//! Artifact-free serving engine for the chaos property suite.
//!
//! [`SimEngine`] wires the *real* admission machinery — [`Batcher`],
//! [`Scheduler`], paged [`KvCacheManager`], [`FaultInjector`] — around a
//! deterministic token function instead of the PJRT runtime, mirroring
//! `Engine::tick`'s structure call for call: the same admissible-now
//! simulation, the same FIFO refill gate, the same lazy growth, the
//! same release-on-retire/cancel paths, and the same fault-injection
//! sites with the same rollback contract (a failed prefill requeues its
//! admitted slots front-first and reclaims their pages).
//!
//! Because the token function is a pure function of the slot's private
//! rng (recreated from the request seed at every admission) and its
//! prompt, a request that is requeued by a fault and admitted again
//! replays its token stream bit-identically — the property the chaos
//! suite pins against a fault-free run of the same seed.  No artifacts,
//! no device: the whole suite runs on a bare checkout.

use anyhow::Result;

use crate::coordinator::batcher::{Batcher, SlotState};
use crate::coordinator::engine::{validate_chunk_config, EngineMetrics};
use crate::coordinator::expert_stats::ExpertStats;
use crate::coordinator::kvcache::host_tier::{HostTierConfig, HostTierStats, PrefixKv};
use crate::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use crate::coordinator::mesh::{MeshConfig, MeshSim, OverlapModel, RebalanceConfig};
use crate::coordinator::request::{Request, RequestId, Response, SamplingParams};
use crate::coordinator::scheduler::{
    adaptive_chunk_budget, Action, Scheduler, SchedulerConfig,
};

use super::faults::{FaultInjector, FaultSite};
use super::ServingEngine;

/// Geometry + policy knobs for a [`SimEngine`].
#[derive(Clone, Copy, Debug)]
pub struct SimEngineConfig {
    /// Static decode batch width.
    pub width: usize,
    /// Maximum sequence length per slot.
    pub max_len: usize,
    /// Maximum prompt length (over-long prompts reject at submit).
    pub prompt_width: usize,
    /// Page-pool size including the reserved garbage page.
    pub num_pages: usize,
    /// KV rows per page.
    pub page_size: usize,
    /// Admission-queue bound.
    pub max_queue: usize,
    /// Cache-policy knobs (lazy growth / CoW sharing / retained pool).
    pub kv: KvCacheConfig,
    /// Prefill/decode interleaving policy.
    pub scheduler: SchedulerConfig,
    /// Mixed-phase steps (chunked prefill co-scheduled with decode) —
    /// the same scheduling surface as `EngineConfig::chunked_prefill`.
    pub chunked_prefill: bool,
    /// Per-step prompt-token budget for in-chunked-prefill slots.
    pub prefill_chunk_tokens: usize,
    /// Reservation-ledger overcommit watermark (1.0 = the strict
    /// baseline gate; see `KvCacheConfig::overcommit_factor`).
    pub overcommit_factor: f64,
    /// Host-tier capacity in bytes.  0 disables the tier and keeps the
    /// single-device pool bit-identical to the pre-hierarchy baseline.
    pub host_tier_bytes: usize,
    /// Derive each step's prefill chunk budget from the observed
    /// prompt-load signal and decode population
    /// (`scheduler::adaptive_chunk_budget`) instead of the fixed
    /// `prefill_chunk_tokens`.  Off here (unlike the real engine's
    /// PR-10 default flip) so the chunk-accounting tests keep their
    /// fixed-budget arithmetic.
    pub adaptive_chunking: bool,
    /// Experts in the synthetic routing schedule (the sim derives a
    /// deterministic, hot-skewed expert per decoded token).
    pub num_experts: usize,
    /// Devices in the simulated expert-parallel mesh (1 = no mesh,
    /// bit-identical baseline — the mesh is observational either way).
    pub ep_degree: usize,
    /// Device-load CV threshold for hot-expert replication (0 = off).
    pub rebalance_cv: f64,
}

impl Default for SimEngineConfig {
    fn default() -> Self {
        SimEngineConfig {
            width: 4,
            max_len: 64,
            prompt_width: 32,
            num_pages: 21,
            page_size: 8,
            max_queue: 64,
            kv: KvCacheConfig::default(),
            scheduler: SchedulerConfig::default(),
            chunked_prefill: false,
            prefill_chunk_tokens: 16,
            overcommit_factor: 1.0,
            host_tier_bytes: 0,
            adaptive_chunking: false,
            num_experts: 8,
            ep_degree: 1,
            rebalance_cv: 0.0,
        }
    }
}

/// Artifact-free engine twin (see module docs).
pub struct SimEngine {
    cfg: SimEngineConfig,
    batcher: Batcher,
    scheduler: Scheduler,
    kv: KvCacheManager,
    /// per-slot next position (= current sequence length)
    pos: Vec<usize>,
    faults: FaultInjector,
    /// Serving metrics (same shape as the real engine's).
    pub metrics: EngineMetrics,
    /// Last prompt-load signal from the front-end
    /// (`ServingEngine::note_prompt_load`), tokens/s.
    prompt_load: f64,
    next_id: u64,
    /// Per-token stream buffer — same contract as the engine's: pushed
    /// only at commit points, drained by [`SimEngine::take_token_events`].
    token_events: Vec<(RequestId, i32)>,
    /// Synthetic per-expert routing telemetry (every decoded token is
    /// assigned a deterministic, hot-skewed expert).
    pub expert_stats: ExpertStats,
    /// Simulated expert-parallel mesh (`None` at `ep_degree: 1`), fed
    /// the same synthetic counts — observational only, like the real
    /// engine's.
    mesh: Option<MeshSim>,
}

impl SimEngine {
    /// Build a sim engine over a paged KV pool of `cfg`'s geometry.
    /// Panics on an invalid chunk config — use [`SimEngine::try_new`]
    /// to handle that as an error.
    pub fn new(cfg: SimEngineConfig) -> Self {
        SimEngine::try_new(cfg).expect("valid sim config")
    }

    /// Fallible constructor: rejects chunk budgets the mixed scheduler
    /// cannot honour, with the same typed error as `Engine::new`.
    pub fn try_new(cfg: SimEngineConfig) -> Result<Self> {
        assert!(
            cfg.max_len % cfg.page_size == 0,
            "max_len must be page-aligned"
        );
        validate_chunk_config(
            cfg.chunked_prefill,
            cfg.prefill_chunk_tokens,
            Some(cfg.page_size),
        )
        .map_err(anyhow::Error::new)?;
        anyhow::ensure!(
            cfg.overcommit_factor.is_finite() && cfg.overcommit_factor >= 1.0,
            "overcommit factor must be a finite value >= 1.0, got {}",
            cfg.overcommit_factor
        );
        anyhow::ensure!(
            cfg.ep_degree >= 1,
            "ep_degree must be >= 1 (1 = no expert parallelism), got {}",
            cfg.ep_degree
        );
        anyhow::ensure!(cfg.num_experts >= 1, "num_experts must be >= 1");
        anyhow::ensure!(
            cfg.rebalance_cv.is_finite() && cfg.rebalance_cv >= 0.0,
            "rebalance_cv must be a finite value >= 0.0 (0 disables), got {}",
            cfg.rebalance_cv
        );
        let mut kv_cfg = cfg.kv;
        kv_cfg.chunk_rows = cfg.chunked_prefill.then_some(cfg.prefill_chunk_tokens);
        kv_cfg.overcommit_factor = cfg.overcommit_factor;
        kv_cfg.host_tier = HostTierConfig {
            capacity_bytes: cfg.host_tier_bytes,
            // a sim KV page holds `page_size` rows of 256 logical bytes
            // each — fixed so host-tier byte arithmetic is deterministic
            page_bytes: cfg.page_size * 256,
        };
        let kv = KvCacheManager::paged(
            cfg.width,
            cfg.max_len,
            cfg.num_pages,
            cfg.page_size,
            cfg.max_len / cfg.page_size,
            kv_cfg,
        );
        Ok(SimEngine {
            batcher: Batcher::new(cfg.width, cfg.max_queue),
            scheduler: Scheduler::new(cfg.scheduler),
            kv,
            pos: vec![0; cfg.width],
            faults: FaultInjector::disabled(),
            metrics: EngineMetrics::default(),
            prompt_load: 0.0,
            next_id: 0,
            token_events: Vec::new(),
            expert_stats: ExpertStats::new(cfg.num_experts),
            mesh: (cfg.ep_degree > 1).then(|| {
                MeshSim::new(MeshConfig {
                    ep_degree: cfg.ep_degree,
                    num_experts: cfg.num_experts,
                    rebalance: (cfg.rebalance_cv > 0.0).then(|| RebalanceConfig {
                        cv_threshold: cfg.rebalance_cv,
                        ..RebalanceConfig::default()
                    }),
                    model: OverlapModel::default(),
                })
            }),
            cfg,
        })
    }

    /// The simulated expert-parallel mesh, when `ep_degree > 1`.
    pub fn mesh(&self) -> Option<&MeshSim> {
        self.mesh.as_ref()
    }

    /// Drain the per-token stream buffer (same contract as the engine).
    pub fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        std::mem::take(&mut self.token_events)
    }

    /// Arm a deterministic fault schedule (same sites as the engine).
    pub fn inject_faults(&mut self, faults: FaultInjector) {
        self.faults = faults;
    }

    /// Page-allocator conservation audit; panics on violation.  With a
    /// mesh, also reconciles its per-device byte/token ledgers
    /// ([`MeshStats::check`](crate::coordinator::mesh::MeshStats::check)).
    pub fn audit(&self) {
        self.kv.audit();
        if let Some(mesh) = &self.mesh {
            mesh.stats().check();
        }
    }

    /// Conservation counters: (admitted, finished, active, queued).
    pub fn accounting(&self) -> (u64, u64, u64, u64) {
        self.batcher.accounting()
    }

    /// Free pages promised to in-flight slots for lazy growth.
    pub fn page_reservations(&self) -> Option<usize> {
        self.kv.reservations()
    }

    /// Submit a request — same contract as `Engine::submit`:
    /// `Ok(Some(id))` queued, `Ok(None)` queue backpressure, `Err`
    /// never admissible.
    pub fn submit(
        &mut self, prompt: Vec<i32>, params: SamplingParams,
    ) -> Result<Option<RequestId>> {
        anyhow::ensure!(
            prompt.len() <= self.cfg.prompt_width,
            "prompt of {} tokens exceeds the sim prompt width {}",
            prompt.len(),
            self.cfg.prompt_width
        );
        if !self.kv.ever_admissible(prompt.len(), params.max_new_tokens) {
            anyhow::bail!(
                "request needs {} KV pages worst-case but the pool only holds {}",
                self.kv.pages_needed(prompt.len(), params.max_new_tokens),
                self.kv.page_budget().map_or(0, |(_, usable)| usable)
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, prompt, params);
        let rid = req.id;
        if self.batcher.submit(req) {
            Ok(Some(rid))
        } else {
            Ok(None)
        }
    }

    /// Drive one tick — the same decision structure as `Engine::tick`.
    pub fn tick(&mut self) -> Result<Vec<Response>> {
        if self.cfg.chunked_prefill {
            let out = self.tick_mixed();
            self.sync_kv_metrics();
            return out;
        }
        self.promote_head();
        let (_, _, active, queued) = self.batcher.accounting();
        let empty = self.cfg.width - active as usize;
        let admissible = self.kv.admissible_now(
            self.batcher
                .queued_requests()
                .map(|r| (r.prompt.as_slice(), r.params.max_new_tokens)),
            queued as usize,
            empty,
        );
        if admissible == 0 && queued > 0 && empty > 0 {
            self.metrics.page_stalls += 1;
        }
        let oldest = self.batcher.oldest_wait();
        let action = self.scheduler.decide(admissible, empty, active as usize, oldest);
        let out = match action {
            Action::Prefill => self.do_prefill(),
            Action::Decode => self.do_decode(),
            Action::Idle => {
                anyhow::ensure!(
                    self.batcher.idle(),
                    "scheduler idled with work queued or in flight"
                );
                Ok(Vec::new())
            }
        };
        self.sync_kv_metrics();
        out
    }

    /// Mixed-phase step — `Engine::tick_mixed`'s four phases (admit →
    /// plan → pre-check → commit) minus the device-only fault sites:
    /// the sim's monolithic path only ever checks `Prefill` and
    /// `Decode`, so the mixed path pre-checks exactly those two, keeping
    /// sim-vs-sim chaos comparisons self-consistent.  An injected fault
    /// commits nothing (admitted slots stay in-chunked-prefill; their
    /// rng streams are untouched, so the retried step replays
    /// bit-identically).
    fn tick_mixed(&mut self) -> Result<Vec<Response>> {
        self.promote_head();
        let (_, _, active, queued) = self.batcher.accounting();
        let empty = self.cfg.width - active as usize;
        let admissible = self.kv.admissible_now(
            self.batcher
                .queued_requests()
                .map(|r| (r.prompt.as_slice(), r.params.max_new_tokens)),
            queued as usize,
            empty,
        );
        if admissible == 0 && queued > 0 && empty > 0 {
            self.metrics.page_stalls += 1;
        }
        let mut chunking = self.batcher.chunking_slots();
        let decoding = self.batcher.decoding_slots();
        let step = self
            .scheduler
            .decide_mixed(admissible, empty, chunking.len(), decoding.len());
        if step.is_idle() {
            anyhow::ensure!(
                self.batcher.idle(),
                "mixed scheduler idled with work queued or in flight"
            );
            return Ok(Vec::new());
        }

        if step.admit {
            let kv = &mut self.kv;
            let filled = self
                .batcher
                .refill_chunked_with(|req| kv.admit(&req.prompt, req.params.max_new_tokens));
            for &slot in &filled {
                self.kv.install(slot);
                self.pos[slot] = 0;
                self.resume_if_swapped(slot);
            }
            debug_assert_eq!(self.kv.pending_installs(), 0, "admissions left unbound");
            let active = self.batcher.accounting().2;
            self.metrics.peak_admitted = self.metrics.peak_admitted.max(active);
            chunking.extend(filled);
            chunking.sort_unstable();
        }

        let mut budget = self.chunk_budget(decoding.len());
        let mut advances: Vec<(usize, usize, usize)> = Vec::new(); // (slot, cursor', took)
        let mut finishers: Vec<usize> = Vec::new();
        for &i in &chunking {
            let slot = &self.batcher.slots()[i];
            let plen = slot.prompt.len().min(self.cfg.prompt_width).max(1);
            if slot.prefilled >= plen {
                finishers.push(i);
                continue;
            }
            if budget == 0 {
                continue;
            }
            let take = (plen - slot.prefilled).min(budget);
            budget -= take;
            let cursor = slot.prefilled + take;
            advances.push((i, cursor, take));
            if cursor >= plen {
                finishers.push(i);
            }
        }

        if !finishers.is_empty() {
            self.faults
                .check(FaultSite::Prefill)
                .map_err(anyhow::Error::new)?;
        }
        if !decoding.is_empty() {
            self.faults
                .check(FaultSite::Decode)
                .map_err(anyhow::Error::new)?;
        }

        let advanced = !advances.is_empty();
        let mut dropped: Vec<usize> = Vec::new();
        for &(i, cursor, took) in &advances {
            if self.kv.grow_prefill(i, cursor).is_err() {
                // chunk growth ran dry under overcommit: demote retained
                // prefixes to the host tier and retry once; if the pool
                // is still dry, put the slot back at the queue head (the
                // fault-requeue path — no token sampled yet, so its
                // eventual replay is bit-identical)
                self.kv
                    .reclaim_for_growth(took / self.cfg.page_size.max(1) + 1);
                if self.kv.grow_prefill(i, cursor).is_err() {
                    if self.batcher.requeue(i) {
                        self.kv.release(i, false);
                        self.pos[i] = 0;
                        self.metrics.preemptions += 1;
                    }
                    dropped.push(i);
                    continue;
                }
            }
            self.batcher.slot_mut(i).prefilled = cursor;
            self.metrics.prefill_chunks += 1;
            self.metrics.chunk_tokens_prefilled += took as u64;
        }
        if !dropped.is_empty() {
            finishers.retain(|i| !dropped.contains(i));
        }
        let mut responses = Vec::new();
        if !finishers.is_empty() {
            self.metrics.prefills += 1;
            for &i in &finishers {
                let plen = self.batcher.slots()[i].prompt.len();
                let id = match self.batcher.slots()[i].state {
                    SlotState::Prefilling(id) | SlotState::Chunking(id) => id,
                    ref s => anyhow::bail!("prefilled slot {i} in state {s:?}"),
                };
                let first = self.sim_token(i);
                self.pos[i] = plen;
                self.batcher.complete_prefill(i, first);
                self.kv.mark_prefilled(i);
                self.emit_token(i, id, first, true);
                self.metrics.generated_tokens += 1;
                if let Some(resp) = self.maybe_finish(i, first) {
                    responses.push(resp);
                }
            }
        }
        let decoding = if decoding.is_empty() {
            decoding
        } else {
            self.ensure_decode_growth(decoding)?
        };
        if !decoding.is_empty() {
            if advanced {
                self.metrics.mixed_steps += 1;
            }
            for &i in &decoding {
                self.kv.grow_to(i, self.pos[i])?;
            }
            self.metrics.decode_steps += 1;
            let mut counts = vec![0u64; self.cfg.num_experts];
            for i in decoding {
                let id = match self.batcher.slots()[i].state {
                    SlotState::Decoding(id) => id,
                    ref s => anyhow::bail!("decoding slot {i} in state {s:?}"),
                };
                let tok = self.sim_token(i);
                counts[sim_expert(tok, self.cfg.num_experts)] += 1;
                self.pos[i] = (self.pos[i] + 1).min(self.cfg.max_len - 1);
                self.emit_token(i, id, tok, false);
                self.metrics.generated_tokens += 1;
                if let Some(resp) = self.maybe_finish(i, tok) {
                    responses.push(resp);
                }
            }
            self.observe_experts(&counts);
        }
        Ok(responses)
    }

    /// Host-tier promotion pre-step: before the admission phase,
    /// re-promote the tier's best cached prefix for the queue head so
    /// the admission simulation and the gate both see the promoted
    /// entry through the ordinary retained-pool lookup.
    fn promote_head(&mut self) {
        if !self.kv.host_tier_enabled() {
            return;
        }
        let Some(prompt) = self
            .batcher
            .queued_requests()
            .next()
            .map(|r| r.prompt.clone())
        else {
            return;
        };
        self.kv.promote_for(&prompt);
    }

    /// Book the host→device restore for a just-admitted slot whose
    /// request was swapped out by a preemption (no-op otherwise).  The
    /// pages themselves re-enter through prefill seed-replay.
    fn resume_if_swapped(&mut self, slot: usize) {
        let id = match self.batcher.slots()[slot].state {
            SlotState::Prefilling(id) | SlotState::Chunking(id) => id,
            _ => return,
        };
        if self.kv.swap_in(id.0).is_some() {
            self.metrics.swap_ins += 1;
        }
    }

    /// This step's prompt-token chunk budget: the fixed configuration
    /// value, or — with `adaptive_chunking` — the budget derived from
    /// the front-end's prompt-load signal and the decode population.
    fn chunk_budget(&self, decode_population: usize) -> usize {
        if !self.cfg.adaptive_chunking {
            return self.cfg.prefill_chunk_tokens;
        }
        adaptive_chunk_budget(
            self.cfg.prefill_chunk_tokens,
            self.cfg.page_size,
            self.prompt_load,
            decode_population,
            self.cfg.width,
        )
    }

    /// Ensure every decoding slot can take its next-token KV write.
    /// When overcommitted growth runs dry: (1) demote retained prefixes
    /// to the host tier, (2) preempt victims — youngest-decode-first,
    /// never a CoW donor with live sharers — swapping their private
    /// pages to the host tier, (3) as the last resort plainly requeue
    /// the youngest decoder (always legal: releasing shared pages only
    /// drops refcounts, and seed-replay regenerates the state).
    /// Returns the decode set that survives this step.
    fn ensure_decode_growth(&mut self, mut decoding: Vec<usize>) -> Result<Vec<usize>> {
        loop {
            let growers: Vec<(usize, usize)> =
                decoding.iter().map(|&i| (i, self.pos[i])).collect();
            let deficit = self.kv.growth_deficit(&growers);
            if deficit == 0 {
                return Ok(decoding);
            }
            if self.kv.reclaim_for_growth(deficit) > 0 {
                continue;
            }
            if let Some(victim) = self.kv.pick_victim(&decoding) {
                self.preempt_slot(victim, true);
                decoding.retain(|&i| i != victim);
                continue;
            }
            let Some(victim) = self.kv.youngest_slot(&decoding) else {
                anyhow::bail!(
                    "decode growth ran dry ({deficit} pages short) with no \
                     preemptible slot"
                );
            };
            self.preempt_slot(victim, false);
            decoding.retain(|&i| i != victim);
        }
    }

    /// Preempt one decoding slot: move its private pages to the host
    /// tier (`swap` — plain release otherwise) and requeue the request
    /// at the queue head carrying its exactly-once `emitted` cursor.
    fn preempt_slot(&mut self, slot: usize, swap: bool) {
        let SlotState::Decoding(id) = self.batcher.slots()[slot].state else {
            return;
        };
        if !(swap && self.kv.swap_out(slot, id.0, None).is_some()) {
            self.kv.release(slot, false);
        }
        self.batcher.preempt(slot);
        self.pos[slot] = 0;
        self.metrics.preemptions += 1;
    }

    /// Push a token event unless it re-delivers a token the client
    /// already received before a preemption (the slot's `emitted`
    /// cursor — exactly-once streaming across seed-replays).
    /// `already_recorded` says whether this token has been pushed into
    /// the slot's `generated` yet at the call site.
    fn emit_token(&mut self, slot: usize, id: RequestId, tok: i32, already_recorded: bool) {
        let s = &self.batcher.slots()[slot];
        if s.generated.len() + usize::from(!already_recorded) > s.emitted {
            self.token_events.push((id, tok));
        }
    }

    fn sync_kv_metrics(&mut self) {
        // the sim moves no real bytes — discard the tier's op log so it
        // cannot grow without bound
        let _ = self.kv.take_host_ops();
        let m = self.kv.metrics().clone();
        self.metrics.page_grows = m.page_grows;
        self.metrics.shared_pages = m.shared_pages;
        self.metrics.cow_copies = m.cow_copies;
        self.metrics.prefix_hits = m.prefix_hits;
        self.metrics.prefix_hit_tokens = m.prefix_hit_tokens;
        self.metrics.evictions = m.evictions;
    }

    fn do_prefill(&mut self) -> Result<Vec<Response>> {
        let kv = &mut self.kv;
        let filled = self
            .batcher
            .refill_with(|req| kv.admit(&req.prompt, req.params.max_new_tokens));
        for &slot in &filled {
            self.kv.install(slot);
            self.resume_if_swapped(slot);
        }
        debug_assert_eq!(self.kv.pending_installs(), 0, "admissions left unbound");
        let active = self.batcher.accounting().2;
        self.metrics.peak_admitted = self.metrics.peak_admitted.max(active);
        if filled.is_empty() {
            return self.do_decode();
        }
        // the injected fault fires before any slot state advances — the
        // same rollback contract as the engine's prefill: requeue
        // front-first (reversed) and reclaim pages + reservations
        if let Err(e) = self.faults.check(FaultSite::Prefill) {
            for &slot in filled.iter().rev() {
                if self.batcher.requeue(slot) {
                    self.kv.release(slot, false);
                }
            }
            return Err(anyhow::Error::new(e));
        }
        self.metrics.prefills += 1;
        let mut responses = Vec::new();
        for &i in &filled {
            let plen = self.batcher.slots()[i].prompt.len();
            let id = match self.batcher.slots()[i].state {
                SlotState::Prefilling(id) | SlotState::Chunking(id) => id,
                ref s => anyhow::bail!("prefilled slot {i} in state {s:?}"),
            };
            let first = self.sim_token(i);
            self.pos[i] = plen;
            self.batcher.complete_prefill(i, first);
            self.kv.mark_prefilled(i);
            self.emit_token(i, id, first, true);
            self.metrics.generated_tokens += 1;
            if let Some(resp) = self.maybe_finish(i, first) {
                responses.push(resp);
            }
        }
        Ok(responses)
    }

    fn do_decode(&mut self) -> Result<Vec<Response>> {
        let decoding = self.batcher.decoding_slots();
        if decoding.is_empty() {
            return Ok(Vec::new());
        }
        let decoding = self.ensure_decode_growth(decoding)?;
        if decoding.is_empty() {
            return Ok(Vec::new());
        }
        for &i in &decoding {
            self.kv.grow_to(i, self.pos[i])?;
        }
        // growth is idempotent, so a fault here is replayed exactly by
        // the retried tick — mirroring the engine's decode site
        self.faults
            .check(FaultSite::Decode)
            .map_err(anyhow::Error::new)?;
        self.metrics.decode_steps += 1;
        let mut responses = Vec::new();
        let mut counts = vec![0u64; self.cfg.num_experts];
        for i in decoding {
            let id = match self.batcher.slots()[i].state {
                SlotState::Decoding(id) => id,
                ref s => anyhow::bail!("decoding slot {i} in state {s:?}"),
            };
            let tok = self.sim_token(i);
            counts[sim_expert(tok, self.cfg.num_experts)] += 1;
            self.pos[i] = (self.pos[i] + 1).min(self.cfg.max_len - 1);
            self.emit_token(i, id, tok, false);
            self.metrics.generated_tokens += 1;
            if let Some(resp) = self.maybe_finish(i, tok) {
                responses.push(resp);
            }
        }
        self.observe_experts(&counts);
        Ok(responses)
    }

    /// Deterministic stand-in for sample-from-logits: a pure function
    /// of the slot's private rng stream and its prompt, so identical
    /// (seed, prompt) admissions replay identical token streams.
    fn sim_token(&mut self, idx: usize) -> i32 {
        let slot = self.batcher.slot_mut(idx);
        let h = slot.prompt.iter().fold(0x9E37u64, |acc, &t| {
            acc.wrapping_mul(0x0100_0000_01B3).wrapping_add(t as u64)
        });
        ((slot.rng.next_u64() ^ h) & 0x7FFF) as i32
    }

    /// Record one decode step's synthetic per-expert routing counts:
    /// stats always, the mesh when enabled.  Reads the token stream the
    /// step already committed, so enabling a mesh can never perturb it.
    fn observe_experts(&mut self, counts: &[u64]) {
        self.expert_stats.record_counts(counts);
        if let Some(mesh) = self.mesh.as_mut() {
            mesh.observe_step(counts);
        }
    }

    fn maybe_finish(&mut self, slot: usize, tok: i32) -> Option<Response> {
        let resp = self.batcher.push_token(slot, tok)?;
        self.kv.release(slot, true);
        self.pos[slot] = 0;
        self.metrics.completed += 1;
        self.metrics.ttft.record(resp.ttft);
        self.metrics.latency.record(resp.latency);
        Some(resp)
    }

    /// Cancel one request (queued or in-flight), reclaiming its pages.
    pub fn cancel(&mut self, id: RequestId) -> Option<Response> {
        let (resp, slot) = self.batcher.abort(id)?;
        if let Some(slot) = slot {
            self.kv.release(slot, false);
            self.pos[slot] = 0;
        }
        // a request cancelled while preempted-and-queued still holds a
        // host pin; drop it without a restore transfer
        self.kv.drop_swapped(id.0);
        self.metrics.aborted += 1;
        self.sync_kv_metrics();
        Some(resp)
    }

    /// Abort every queued and in-flight request (drain).
    pub fn abort_all(&mut self) -> Vec<Response> {
        let out = self.batcher.abort_all();
        for slot in 0..self.cfg.width {
            self.kv.release(slot, false);
            self.pos[slot] = 0;
        }
        self.kv.drop_all_swapped();
        self.metrics.aborted += out.len() as u64;
        self.sync_kv_metrics();
        out
    }

    /// Requests waiting for a slot.
    pub fn queue_len(&self) -> usize {
        self.batcher.queue_len()
    }

    /// True when no work remains anywhere.
    pub fn is_idle(&self) -> bool {
        self.batcher.idle()
    }

    /// Reclaimable / usable pool pages.
    pub fn page_budget(&self) -> Option<(usize, usize)> {
        self.kv.page_budget()
    }

    /// True while `id` has produced no token yet.
    pub fn awaiting_first_token(&self, id: RequestId) -> bool {
        self.batcher.awaiting_first_token(id)
    }

    /// Host-tier occupancy in bytes (0 without a tier).
    pub fn host_tier_bytes(&self) -> usize {
        self.kv.host_tier_bytes()
    }

    /// Host-tier transfer/occupancy stats (`None` on dense layouts).
    pub fn host_tier_stats(&self) -> Option<&HostTierStats> {
        self.kv.host_tier_stats()
    }
}

impl ServingEngine for SimEngine {
    fn submit(
        &mut self, prompt: Vec<i32>, params: SamplingParams,
    ) -> Result<Option<RequestId>> {
        SimEngine::submit(self, prompt, params)
    }
    fn tick(&mut self) -> Result<Vec<Response>> {
        SimEngine::tick(self)
    }
    fn cancel(&mut self, id: RequestId) -> Option<Response> {
        SimEngine::cancel(self, id)
    }
    fn abort_all(&mut self) -> Vec<Response> {
        SimEngine::abort_all(self)
    }
    fn is_idle(&self) -> bool {
        SimEngine::is_idle(self)
    }
    fn queue_len(&self) -> usize {
        SimEngine::queue_len(self)
    }
    fn page_budget(&self) -> Option<(usize, usize)> {
        SimEngine::page_budget(self)
    }
    fn awaiting_first_token(&self, id: RequestId) -> bool {
        SimEngine::awaiting_first_token(self, id)
    }
    fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }
    fn metrics_mut(&mut self) -> &mut EngineMetrics {
        &mut self.metrics
    }
    fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        SimEngine::take_token_events(self)
    }
    /// Warm-start the replica's retained prefix pool from the host
    /// prefix store.  Safe in the simulator because sim tokens are a
    /// pure function of (seed, prompt) — warmed pages change admission
    /// arithmetic, never output tokens.  With a host tier the pages
    /// route through it (ingest + promote); without one this is the
    /// direct preload of the pre-hierarchy baseline.
    fn warm_prefix(&mut self, prompt: &[i32]) -> usize {
        self.kv.warm_prefix_host(prompt, None)
    }
    fn warm_prefix_kv(&mut self, prompt: &[i32], payload: Option<&PrefixKv>) -> usize {
        self.kv.warm_prefix_host(prompt, payload)
    }
    fn export_prefix(&mut self, prompt: &[i32]) -> Option<PrefixKv> {
        // the sim holds no real bytes: the returned payload carries
        // page counts and tokens only, which is all sim warm-starts use
        self.kv.export_prefix(prompt).map(|(kv, _pages)| kv)
    }
    fn note_prompt_load(&mut self, prompt_tokens_per_s: f64) {
        self.prompt_load = prompt_tokens_per_s;
    }
}

/// Deterministic, hot-skewed expert assignment for one simulated token.
///
/// A pure function of the already-committed token, so expert telemetry
/// (and any mesh consuming it) can never perturb the token stream.  The
/// quadratic map `e = ⌊E·x²/M²⌋` over a hashed uniform `x` puts
/// `P(e=k) = √((k+1)/E) − √(k/E)` — monotonically decreasing in `k` —
/// so low expert ids run hot, giving the mesh the routing skew the
/// paper's telemetry sections report.
fn sim_expert(tok: i32, num_experts: usize) -> usize {
    const M: u64 = 1 << 12;
    let h = (tok as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40;
    let x = h % M;
    ((x * x * num_experts as u64) / (M * M)) as usize
}

#[cfg(test)]
mod tests {
    use super::super::faults::FaultKind;
    use super::*;

    fn run_all(engine: &mut SimEngine) -> Vec<Response> {
        let mut out = Vec::new();
        let mut guard = 0;
        while !engine.is_idle() {
            out.extend(engine.tick().expect("fault-free tick"));
            engine.audit();
            guard += 1;
            assert!(guard < 10_000, "sim failed to drain");
        }
        out
    }

    fn submit_batch(engine: &mut SimEngine, n: u64) {
        for i in 0..n {
            let prompt: Vec<i32> = (0..4 + (i % 5) as i32).map(|j| 1 + j).collect();
            let params = SamplingParams {
                max_new_tokens: 2 + (i % 4) as usize,
                seed: i,
                ..Default::default()
            };
            engine
                .submit(prompt, params)
                .expect("admissible")
                .expect("queued");
        }
    }

    #[test]
    fn fault_free_run_completes_and_conserves() {
        let mut engine = SimEngine::new(SimEngineConfig::default());
        submit_batch(&mut engine, 10);
        let responses = run_all(&mut engine);
        assert_eq!(responses.len(), 10);
        assert_eq!(engine.metrics.completed, 10);
        let (reclaimable, usable) = engine.page_budget().expect("paged");
        assert_eq!(reclaimable, usable, "full pool reclaimable after drain");
        assert_eq!(engine.page_reservations(), Some(0));
    }

    #[test]
    fn transient_prefill_fault_requeues_and_replays_identically() {
        let tokens_of = |faults: Option<FaultInjector>| -> Vec<(u64, Vec<i32>)> {
            let mut engine = SimEngine::new(SimEngineConfig::default());
            if let Some(f) = faults {
                engine.inject_faults(f);
            }
            submit_batch(&mut engine, 6);
            let mut out = Vec::new();
            let mut guard = 0;
            while !engine.is_idle() {
                match engine.tick() {
                    Ok(rs) => out.extend(rs),
                    Err(e) => {
                        assert!(
                            super::super::faults::fault_kind(&e).is_some(),
                            "only injected faults expected: {e:#}"
                        );
                    }
                }
                engine.audit();
                guard += 1;
                assert!(guard < 10_000, "sim failed to drain");
            }
            let mut pairs: Vec<(u64, Vec<i32>)> =
                out.into_iter().map(|r| (r.id.0, r.tokens)).collect();
            pairs.sort();
            pairs
        };
        let baseline = tokens_of(None);
        let faulted = tokens_of(Some(FaultInjector::scripted([
            (0, FaultKind::Transient),
            (2, FaultKind::Transient),
        ])));
        assert_eq!(baseline, faulted, "retried requests replay bit-identically");
    }

    #[test]
    fn try_new_rejects_degenerate_chunk_budgets() {
        let cfg = SimEngineConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 0,
            ..Default::default()
        };
        assert!(SimEngine::try_new(cfg).is_err(), "zero chunk budget");
        let cfg = SimEngineConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 4, // below page_size = 8
            ..Default::default()
        };
        assert!(SimEngine::try_new(cfg).is_err(), "sub-page chunk budget");
        let cfg = SimEngineConfig {
            chunked_prefill: false,
            prefill_chunk_tokens: 0,
            ..Default::default()
        };
        assert!(SimEngine::try_new(cfg).is_ok(), "budget unused when monolithic");
    }

    /// Chunked pacing must not change a single generated token: the sim
    /// token is a pure function of (seed, prompt), so monolithic and
    /// mixed-phase schedules of the same arrivals produce bit-identical
    /// per-request streams — only the interleaving differs.
    #[test]
    fn chunked_schedule_is_bit_identical_to_monolithic() {
        let tokens_of = |chunked: bool| -> (Vec<(u64, Vec<i32>)>, EngineMetrics) {
            let mut engine = SimEngine::new(SimEngineConfig {
                chunked_prefill: chunked,
                prefill_chunk_tokens: 8,
                ..Default::default()
            });
            for i in 0..6u64 {
                let plen = 10 + (i % 3) as i32 * 5; // 10 / 15 / 20 tokens
                let prompt: Vec<i32> = (0..plen).map(|j| 1 + j).collect();
                let params = SamplingParams {
                    max_new_tokens: 3 + (i % 3) as usize,
                    seed: i,
                    ..Default::default()
                };
                engine
                    .submit(prompt, params)
                    .expect("admissible")
                    .expect("queued");
            }
            let out = run_all(&mut engine);
            let mut pairs: Vec<(u64, Vec<i32>)> =
                out.into_iter().map(|r| (r.id.0, r.tokens)).collect();
            pairs.sort();
            (pairs, engine.metrics.clone())
        };
        let (mono, mono_m) = tokens_of(false);
        let (chunked, m) = tokens_of(true);
        assert_eq!(mono, chunked, "pacing must not change tokens");
        assert_eq!(mono_m.prefill_chunks, 0, "monolithic path never chunks");
        assert!(
            m.prefill_chunks as usize > chunked.len(),
            "multi-chunk prefills happened ({} chunks for {} requests)",
            m.prefill_chunks,
            chunked.len()
        );
        assert!(m.mixed_steps > 0, "chunks co-scheduled with decode steps");
    }

    #[test]
    fn mid_chunk_cancel_reclaims_pages_and_reservations() {
        let mut engine = SimEngine::new(SimEngineConfig {
            chunked_prefill: true,
            prefill_chunk_tokens: 8,
            ..Default::default()
        });
        let prompt: Vec<i32> = (0..20).collect();
        let params = SamplingParams {
            max_new_tokens: 8,
            seed: 7,
            ..Default::default()
        };
        let id = engine.submit(prompt, params).unwrap().unwrap();
        // one tick admits the request and walks its first 8-token chunk;
        // the remaining pages are still held as reservations
        engine.tick().expect("fault-free tick");
        assert!(!engine.is_idle(), "prefill is mid-chunk");
        assert!(
            engine.page_reservations().unwrap() > 0,
            "unchunked tail still reserved"
        );
        let resp = engine.cancel(id).expect("in-flight cancel");
        assert!(resp.tokens.is_empty(), "cancelled before first token");
        let (reclaimable, usable) = engine.page_budget().unwrap();
        assert_eq!(reclaimable, usable, "all pages reclaimed after cancel");
        assert_eq!(engine.page_reservations(), Some(0), "reservations freed");
        engine.audit();
    }

    /// The tentpole end-to-end property: a run that overcommits its
    /// reservations, preempts the youngest decode to the host tier, and
    /// later re-admits it must produce bit-identical tokens to a run
    /// with enough memory to never preempt — and must stream every
    /// token exactly once across the swap.
    #[test]
    fn preempted_run_tokens_equal_unpreempted_run() {
        type Streams = std::collections::BTreeMap<u64, Vec<i32>>;
        let run = |cfg: SimEngineConfig| -> (Vec<(u64, Vec<i32>)>, Streams, SimEngine) {
            let mut engine = SimEngine::new(cfg);
            for i in 0..3u64 {
                let prompt: Vec<i32> = (0..8).map(|j| 100 * i as i32 + j).collect();
                let params = SamplingParams {
                    max_new_tokens: 17,
                    seed: 40 + i,
                    ..Default::default()
                };
                engine.submit(prompt, params).expect("admissible").expect("queued");
            }
            let mut streams = Streams::new();
            let mut out = Vec::new();
            let mut guard = 0;
            while !engine.is_idle() {
                out.extend(engine.tick().expect("fault-free tick"));
                for (id, tok) in engine.take_token_events() {
                    streams.entry(id.0).or_default().push(tok);
                }
                engine.audit();
                guard += 1;
                assert!(guard < 10_000, "sim failed to drain");
            }
            let mut pairs: Vec<(u64, Vec<i32>)> =
                out.into_iter().map(|r| (r.id.0, r.tokens)).collect();
            pairs.sort();
            (pairs, streams, engine)
        };
        // 8 usable pages against 3 requests × 4 pages of reserved
        // demand: factor 2.0 admits all three and decode growth has to
        // preempt a victim to the host tier to keep going.
        let (tight, tight_streams, tight_engine) = run(SimEngineConfig {
            width: 3,
            max_len: 32,
            num_pages: 9,
            page_size: 8,
            overcommit_factor: 2.0,
            host_tier_bytes: 32 * 1024,
            ..Default::default()
        });
        // Roomy baseline: same arrivals, enough pages to never preempt.
        let (roomy, roomy_streams, roomy_engine) = run(SimEngineConfig {
            width: 3,
            max_len: 32,
            num_pages: 16,
            page_size: 8,
            ..Default::default()
        });
        assert_eq!(tight, roomy, "preempted requests replay bit-identically");
        assert!(
            tight_engine.metrics.preemptions > 0,
            "memory pressure forced a preemption"
        );
        assert!(
            tight_engine.metrics.swap_ins > 0,
            "a victim came back from the host tier"
        );
        assert_eq!(roomy_engine.metrics.preemptions, 0, "baseline never preempts");
        // exactly-once streaming: each request's event stream must equal
        // its final token vector despite the mid-stream preemption
        for (id, tokens) in &tight {
            assert_eq!(
                tight_streams.get(id),
                Some(tokens),
                "request {id} streamed exactly once"
            );
        }
        assert_eq!(tight_streams, roomy_streams, "streams agree across schedules");
        let stats = tight_engine.host_tier_stats().expect("paged layout");
        assert_eq!(
            stats.swapped_out_pages,
            stats.swapped_in_pages + stats.dropped_pin_pages,
            "every swapped page was restored or dropped on purpose"
        );
    }

    /// `overcommit_factor: 1.0` with no host tier must leave every new
    /// code path inert: no preemption, no swaps, no tier occupancy —
    /// the pre-hierarchy baseline schedule.
    #[test]
    fn default_config_keeps_overcommit_machinery_inert() {
        let mut engine = SimEngine::new(SimEngineConfig::default());
        submit_batch(&mut engine, 10);
        let responses = run_all(&mut engine);
        assert_eq!(responses.len(), 10);
        assert_eq!(engine.metrics.preemptions, 0, "strict gate never preempts");
        assert_eq!(engine.metrics.swap_ins, 0);
        assert_eq!(engine.host_tier_bytes(), 0);
        assert_eq!(
            engine.host_tier_stats(),
            Some(&HostTierStats::default()),
            "disabled tier never moves a byte"
        );
    }

    /// The mesh is observational: enabling `ep_degree: 2` (with the
    /// rebalancer armed) must leave every generated token bit-identical
    /// to the meshless baseline, while its per-device ledgers reconcile
    /// against the routing telemetry.
    #[test]
    fn mesh_is_observational_and_ledgers_reconcile() {
        let run = |ep_degree: usize, rebalance_cv: f64| {
            let mut engine = SimEngine::try_new(SimEngineConfig {
                ep_degree,
                rebalance_cv,
                ..Default::default()
            })
            .expect("valid mesh config");
            submit_batch(&mut engine, 10);
            let out = run_all(&mut engine);
            let mut pairs: Vec<(u64, Vec<i32>)> =
                out.into_iter().map(|r| (r.id.0, r.tokens)).collect();
            pairs.sort();
            (pairs, engine)
        };
        let (baseline, plain) = run(1, 0.0);
        let (meshed_tokens, meshed) = run(2, 0.25);
        assert_eq!(baseline, meshed_tokens, "the mesh never touches tokens");
        assert!(plain.mesh().is_none(), "ep_degree 1 builds no mesh");
        let stats = meshed.mesh().expect("ep_degree 2 builds a mesh").stats();
        stats.check();
        assert_eq!(
            stats.routed_tokens,
            meshed.expert_stats.total(),
            "every routed token landed on exactly one device"
        );
        assert_eq!(
            stats.routed_tokens, plain.expert_stats.total(),
            "identical schedules route identical token totals"
        );
        assert!(stats.steps > 0, "decode steps were observed");
        assert!(
            stats.overlapped_s <= stats.serial_s,
            "overlap can never lose to the serial schedule"
        );
    }

    #[test]
    fn try_new_rejects_degenerate_mesh_configs() {
        let cfg = SimEngineConfig { ep_degree: 0, ..Default::default() };
        assert!(SimEngine::try_new(cfg).is_err(), "zero devices");
        let cfg = SimEngineConfig { rebalance_cv: f64::NAN, ..Default::default() };
        assert!(SimEngine::try_new(cfg).is_err(), "NaN threshold");
        let cfg = SimEngineConfig { rebalance_cv: -0.5, ..Default::default() };
        assert!(SimEngine::try_new(cfg).is_err(), "negative threshold");
        let cfg = SimEngineConfig { num_experts: 0, ..Default::default() };
        assert!(SimEngine::try_new(cfg).is_err(), "zero experts");
    }
}
