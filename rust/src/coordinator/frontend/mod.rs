//! Open-loop serving front-end: intake, deadlines, fault recovery.
//!
//! The engine is a tick driver; this module is the loop around it that
//! real serving needs.  [`ServeFrontend`] consumes a time-stamped
//! arrival stream *open-loop* (arrivals keep coming whether or not the
//! engine keeps up — the regime where overload behaviour actually
//! shows) and drives any [`ServingEngine`] through four concerns:
//!
//!   * **intake** — every arrival passes the [`IntakePolicy`] gate
//!     before `submit`; refusals carry a typed [`RejectReason`]
//!     (full queue / impossible request / load shed).
//!   * **deadlines** — per-request TTFT deadlines and total-latency
//!     budgets are checked every step; expired requests cancel through
//!     the engine, reclaiming their pages and reservations.
//!   * **fault recovery** — a failed tick is classified via
//!     [`fault_kind`]: transient faults retry the tick with bounded
//!     backoff (an engine whose failed tick left no partial state —
//!     see the injection sites in `Engine::tick` — replays it
//!     bit-identically); anything else is permanent, and the front-end
//!     aborts, drains every admitted request with a typed outcome, and
//!     halts.
//!   * **SLO reporting** — every arrival ends in exactly one
//!     [`RequestOutcome`]; [`ServeFrontend::report`] folds them into a
//!     [`ServeReport`] with TTFT/TPOT/goodput distributions.
//!   * **per-token streaming** — with [`FrontendConfig::stream`] on,
//!     every submitted request gets a [`TokenStream`] channel
//!     ([`ServeFrontend::take_stream`]).  After each successful tick
//!     the front-end drains the engine's per-token commit log
//!     ([`ServingEngine::take_token_events`]) and forwards each token
//!     to its request's channel, *then* processes terminal outcomes —
//!     so a stream always carries its final token before its
//!     [`StreamEvent::End`].  The front-end owns the senders: exactly
//!     one `End` terminates every stream on every terminal path
//!     (completion, cancel, deadline expiry, drain — halting included),
//!     and a failed tick forwards nothing (the engine commits nothing),
//!     so transient-fault retries can never duplicate a token.
//!     Time-to-first-*streamed*-token lands in [`ServeReport::ttfs`].
//!
//! The front-end runs on a wall clock in production and on a virtual
//! (tick-counted) clock in tests ([`ClockMode`]), where a whole chaos
//! run — arrivals, expiries, faults, retries — is deterministic given
//! its seeds.  [`sim::SimEngine`] supplies an artifact-free engine with
//! the same admission/page machinery, so the chaos property suite runs
//! on a bare checkout.

pub mod faults;
pub mod intake;
pub mod sim;
pub mod slo;

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engine::{Engine, EngineMetrics};
use crate::coordinator::kvcache::host_tier::PrefixKv;
use crate::coordinator::request::{RequestId, Response, SamplingParams};
use crate::metrics::Histogram;
use crate::rng::Rng;

use faults::{fault_kind, FaultKind};
use intake::{IntakePolicy, RejectReason};
use slo::ServeReport;

/// The engine surface the front-end drives.  Implemented by the real
/// PJRT [`Engine`] and by the artifact-free [`sim::SimEngine`] the
/// chaos suite runs against.
pub trait ServingEngine {
    /// Submit a request: `Ok(Some(id))` when queued, `Ok(None)` under
    /// queue backpressure, `Err` when the request can never be served.
    fn submit(&mut self, prompt: Vec<i32>, params: SamplingParams)
        -> Result<Option<RequestId>>;
    /// Drive one tick; returns any responses completed during it.
    fn tick(&mut self) -> Result<Vec<Response>>;
    /// Cancel one request wherever it lives, reclaiming its pages.
    fn cancel(&mut self, id: RequestId) -> Option<Response>;
    /// Abort every queued and in-flight request (drain).
    fn abort_all(&mut self) -> Vec<Response>;
    /// True when no work remains anywhere.
    fn is_idle(&self) -> bool;
    /// Requests waiting for a slot.
    fn queue_len(&self) -> usize;
    /// Reclaimable / usable pool pages (`None` on dense layouts).
    fn page_budget(&self) -> Option<(usize, usize)>;
    /// True while `id` has produced no token yet.
    fn awaiting_first_token(&self, id: RequestId) -> bool;
    /// Drain the per-token commit log since the last call: `(request,
    /// token)` pairs in the exact order tokens entered request
    /// outcomes.  Failed ticks commit nothing and log nothing.
    fn take_token_events(&mut self) -> Vec<(RequestId, i32)>;
    /// Serving metrics snapshot.
    fn metrics(&self) -> &EngineMetrics;
    /// Mutable metrics (the front-end books sheds/retries/misses here).
    fn metrics_mut(&mut self) -> &mut EngineMetrics;
    /// Warm-start `prompt`'s full-page prefix into the engine's retained
    /// prefix pool (host prefix store download, see
    /// `coordinator::cluster`).  Returns the pages actually installed.
    /// Default: no-op — the real [`Engine`] keeps it that way until a
    /// device KV upload path exists, because parking pages that hold no
    /// real KV would route prefix sharers at garbage state.  The
    /// simulator overrides it (sim tokens are a pure function of seed
    /// and prompt, so warmed pages only change admission arithmetic).
    fn warm_prefix(&mut self, _prompt: &[i32]) -> usize {
        0
    }
    /// Download the device KV bytes of `prompt`'s longest full-page
    /// prefix for the cluster prefix store (see `coordinator::cluster`).
    /// Routes through the engine's host tier — the only device↔host KV
    /// path — so the copy is booked against `TransferTotals`.  `None`
    /// when the engine holds no such prefix or has no host tier to
    /// stage it in.
    fn export_prefix(&mut self, _prompt: &[i32]) -> Option<PrefixKv> {
        None
    }
    /// [`Self::warm_prefix`] with an optional KV payload previously
    /// downloaded from a peer via [`Self::export_prefix`].  Engines with
    /// a host tier ingest the payload and promote it into the device
    /// pool (a real KV upload); the default delegates to the
    /// logical-only `warm_prefix`.
    fn warm_prefix_kv(&mut self, prompt: &[i32], _payload: Option<&PrefixKv>) -> usize {
        self.warm_prefix(prompt)
    }
    /// Observed prompt-token arrival rate (tokens/s over the
    /// front-end's recent intake window).  Engines with
    /// `adaptive_chunking` enabled size the next prefill chunk budget
    /// from it; the default ignores the signal.
    fn note_prompt_load(&mut self, _prompt_tokens_per_s: f64) {}
}

impl ServingEngine for Engine {
    fn submit(
        &mut self, prompt: Vec<i32>, params: SamplingParams,
    ) -> Result<Option<RequestId>> {
        Engine::submit(self, prompt, params)
    }
    fn tick(&mut self) -> Result<Vec<Response>> {
        Engine::tick(self)
    }
    fn cancel(&mut self, id: RequestId) -> Option<Response> {
        Engine::cancel(self, id)
    }
    fn abort_all(&mut self) -> Vec<Response> {
        Engine::abort_all(self)
    }
    fn is_idle(&self) -> bool {
        Engine::is_idle(self)
    }
    fn queue_len(&self) -> usize {
        Engine::queue_len(self)
    }
    fn page_budget(&self) -> Option<(usize, usize)> {
        Engine::page_budget(self)
    }
    fn awaiting_first_token(&self, id: RequestId) -> bool {
        Engine::awaiting_first_token(self, id)
    }
    fn take_token_events(&mut self) -> Vec<(RequestId, i32)> {
        Engine::take_token_events(self)
    }
    fn metrics(&self) -> &EngineMetrics {
        &self.metrics
    }
    fn metrics_mut(&mut self) -> &mut EngineMetrics {
        &mut self.metrics
    }
    fn export_prefix(&mut self, prompt: &[i32]) -> Option<PrefixKv> {
        Engine::export_prefix(self, prompt)
    }
    fn warm_prefix_kv(&mut self, prompt: &[i32], payload: Option<&PrefixKv>) -> usize {
        Engine::warm_prefix_kv(self, prompt, payload)
    }
    fn note_prompt_load(&mut self, prompt_tokens_per_s: f64) {
        Engine::note_prompt_load(self, prompt_tokens_per_s)
    }
}

/// One event on a request's [`TokenStream`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamEvent {
    /// One committed output token, in generation order.
    Token(i32),
    /// The stream's single terminator: sent exactly once, on whatever
    /// terminal path the request takes (completion, cancel, deadline
    /// expiry, drain).  No event follows it.
    End,
}

/// Receiving half of one request's per-token stream (see
/// [`ServeFrontend::take_stream`]).  Tokens appear as the driving loop
/// ticks; the sequence is always a prefix of the request's final
/// outcome tokens, and equals them exactly when it completes, followed
/// by one [`StreamEvent::End`].  Dropping the stream is fine — the
/// front-end ignores send failures to a departed consumer.
pub struct TokenStream {
    rx: mpsc::Receiver<StreamEvent>,
}

impl TokenStream {
    /// Non-blocking poll: the next event if one is ready.
    pub fn try_next(&self) -> Option<StreamEvent> {
        self.rx.try_recv().ok()
    }

    /// Drain every event currently buffered (non-blocking).
    pub fn drain(&self) -> Vec<StreamEvent> {
        self.rx.try_iter().collect()
    }
}

/// Bounded-retry policy for transient tick faults: capped exponential
/// backoff with deterministic seeded jitter.
///
/// Retry `n` (1-based) waits `min(base_backoff_s * 2^(n-1),
/// max_backoff_s)` seconds, scaled down by up to `jitter_frac` using a
/// jitter value derived purely from `(seed, n)` — so a same-seed replay
/// waits bit-identical durations (the virtual-clock chaos runs depend
/// on this), while distinct seeds decorrelate retry storms across
/// replicas.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Consecutive failed ticks tolerated before escalating to a drain.
    pub max_retries: u32,
    /// First retry's backoff; doubles per subsequent retry.
    pub base_backoff_s: f64,
    /// Exponential growth cap (applied before jitter).
    pub max_backoff_s: f64,
    /// Fraction of the capped backoff the jitter may shave off, in
    /// `[0, 1]`.  `0.0` gives the pure capped-doubling schedule.
    pub jitter_frac: f64,
    /// Jitter seed.  Same seed, same schedule — bit-identical replay.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            base_backoff_s: 0.002,
            max_backoff_s: 0.050,
            jitter_frac: 0.25,
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// The backoff before retry `attempt` (1-based).  A pure function
    /// of the policy and the attempt number: `backoff_s(n)` is in
    /// `[(1 - jitter_frac) * b, b]` where `b = min(base_backoff_s *
    /// 2^(n-1), max_backoff_s)`.
    pub fn backoff_s(&self, attempt: u32) -> f64 {
        let n = attempt.max(1);
        let doubled = self.base_backoff_s * f64::powi(2.0, (n - 1).min(62) as i32);
        let capped = doubled.min(self.max_backoff_s);
        let mut jitter_rng =
            Rng::new(self.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(u64::from(n)));
        capped * (1.0 - self.jitter_frac.clamp(0.0, 1.0) * jitter_rng.uniform())
    }
}

/// How the front-end measures time.
#[derive(Clone, Copy, Debug)]
pub enum ClockMode {
    /// Real wall clock; idle gaps sleep.
    Wall,
    /// Deterministic virtual clock: each tick advances time by
    /// `tick_s`, idle gaps jump straight to the next arrival.  Chaos
    /// tests run here so deadline expiry is seed-reproducible.
    Virtual {
        /// Virtual seconds one engine tick is deemed to take.
        tick_s: f64,
    },
}

/// Front-end configuration.
#[derive(Clone, Copy, Debug)]
pub struct FrontendConfig {
    /// Intake gate (queue bound + shed watermarks).  Its `max_pending`
    /// should not exceed the engine's own `max_queue`, or the engine's
    /// untyped rejection fires first.
    pub intake: IntakePolicy,
    /// Expire a request that produced no token within this many seconds
    /// of submission (`None` disables TTFT deadlines).
    pub ttft_deadline_s: Option<f64>,
    /// Expire a request outright this many seconds after submission
    /// (`None` disables total-latency budgets).
    pub deadline_s: Option<f64>,
    /// Transient-fault retry policy.
    pub retry: RetryPolicy,
    /// Wall or virtual time.
    pub clock: ClockMode,
    /// Open a per-request [`TokenStream`] for every submitted arrival
    /// and forward committed tokens each tick (see the module docs'
    /// streaming bullet).  Off by default: non-streaming callers keep
    /// the exact PR-6 loop.
    pub stream: bool,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            intake: IntakePolicy::default(),
            ttft_deadline_s: None,
            deadline_s: None,
            retry: RetryPolicy::default(),
            clock: ClockMode::Wall,
            stream: false,
        }
    }
}

/// One time-stamped arrival in the open-loop stream.
#[derive(Clone, Debug)]
pub struct ArrivingRequest {
    /// Arrival time, seconds from run start.
    pub at: f64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Generation parameters.
    pub params: SamplingParams,
    /// Caller-chosen stable tag.  Outcomes key on it, not on the
    /// engine's [`RequestId`] (ids burn on queue-full rejections, so
    /// only the tag is comparable across runs).
    pub tag: u64,
}

/// The single terminal outcome of one arrival.
#[derive(Clone, Debug)]
pub enum RequestOutcome {
    /// Finished normally.
    Completed(Response),
    /// Refused at intake with a typed reason.
    Rejected(RejectReason),
    /// Expired on its TTFT deadline before producing a token.
    TtftExpired(Response),
    /// Expired on its total-latency budget.
    DeadlineExpired(Response),
    /// Cancelled by the caller.
    Cancelled(Response),
    /// Drained by a permanent fault.
    Drained(Response),
}

/// What one [`ServeFrontend::step`] left the loop in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontendStatus {
    /// Work (or future arrivals) remain.
    Running,
    /// Every arrival reached a terminal outcome.
    Done,
    /// A permanent fault drained the engine; remaining arrivals are
    /// unserved.
    Halted,
}

struct LiveRequest {
    tag: u64,
    submitted_at: f64,
    /// Whether a token has been forwarded to this request's stream yet
    /// (the time-to-first-streamed-token edge).
    streamed: bool,
}

/// Open-loop driver around a [`ServingEngine`] (see module docs).
pub struct ServeFrontend<E: ServingEngine> {
    engine: E,
    cfg: FrontendConfig,
    started: Instant,
    vnow: f64,
    arrivals: VecDeque<ArrivingRequest>,
    live: HashMap<RequestId, LiveRequest>,
    outcomes: Vec<(u64, RequestOutcome)>,
    /// Sending halves of live requests' streams, owned here so every
    /// terminal path terminates its stream exactly once (removal from
    /// this map IS the termination edge).
    senders: HashMap<RequestId, mpsc::Sender<StreamEvent>>,
    /// Receiving halves parked by tag until the caller collects them.
    streams: HashMap<u64, TokenStream>,
    /// Time-to-first-streamed-token samples (streaming runs only).
    ttfs: Histogram,
    /// Sliding window of recently submitted prompt sizes — `(submit
    /// time, prompt tokens)` — folded into the prompt-load signal the
    /// engine's adaptive chunking consumes ([`ServingEngine::note_prompt_load`]).
    recent_prompts: VecDeque<(f64, usize)>,
    attempts: u32,
    fatal: Option<String>,
    ticks: u64,
}

impl<E: ServingEngine> ServeFrontend<E> {
    /// Wrap an engine; arrivals are loaded with
    /// [`ServeFrontend::push_arrivals`].
    pub fn new(engine: E, cfg: FrontendConfig) -> Self {
        ServeFrontend {
            engine,
            cfg,
            started: Instant::now(),
            vnow: 0.0,
            arrivals: VecDeque::new(),
            live: HashMap::new(),
            outcomes: Vec::new(),
            senders: HashMap::new(),
            streams: HashMap::new(),
            ttfs: Histogram::default(),
            recent_prompts: VecDeque::new(),
            attempts: 0,
            fatal: None,
            ticks: 0,
        }
    }

    /// The wrapped engine.
    pub fn engine(&self) -> &E {
        &self.engine
    }

    /// Mutable access to the wrapped engine.
    pub fn engine_mut(&mut self) -> &mut E {
        &mut self.engine
    }

    /// Load arrivals (merged and kept sorted by arrival time).
    pub fn push_arrivals(&mut self, items: impl IntoIterator<Item = ArrivingRequest>) {
        self.arrivals.extend(items);
        self.arrivals
            .make_contiguous()
            .sort_by(|a, b| a.at.total_cmp(&b.at));
    }

    /// Current time on the configured clock, seconds from run start.
    pub fn now(&self) -> f64 {
        match self.cfg.clock {
            ClockMode::Wall => self.started.elapsed().as_secs_f64(),
            ClockMode::Virtual { .. } => self.vnow,
        }
    }

    /// The permanent fault that halted the run, if any.
    pub fn fatal(&self) -> Option<&str> {
        self.fatal.as_deref()
    }

    /// Terminal outcomes recorded so far, `(tag, outcome)` pairs in
    /// the order they resolved.
    pub fn outcomes(&self) -> &[(u64, RequestOutcome)] {
        &self.outcomes
    }

    /// Ids currently live in the engine, ascending (deterministic).
    pub fn live_ids(&self) -> Vec<RequestId> {
        let mut ids: Vec<RequestId> = self.live.keys().copied().collect();
        ids.sort();
        ids
    }

    /// Collect the [`TokenStream`] of the arrival tagged `tag`, if one
    /// was opened (streaming on, the arrival was submitted) and has not
    /// been collected yet.  The stream is yours from here; the
    /// front-end keeps only the sending half.
    pub fn take_stream(&mut self, tag: u64) -> Option<TokenStream> {
        self.streams.remove(&tag)
    }

    /// Terminate `id`'s stream with its single [`StreamEvent::End`].
    /// Dropping the sender from the map makes the edge exactly-once:
    /// every terminal path calls this, and only the first call finds a
    /// sender.
    fn finish_stream(&mut self, id: RequestId) {
        if let Some(tx) = self.senders.remove(&id) {
            let _ = tx.send(StreamEvent::End);
        }
    }

    /// Forward the engine's committed tokens to their streams (in
    /// commit order), recording the first-streamed-token edge per
    /// request.  Called only after a *successful* tick — a failed tick
    /// commits nothing, so retries can never duplicate a token.
    fn forward_token_events(&mut self) {
        let events = self.engine.take_token_events();
        if !self.cfg.stream {
            return;
        }
        let now = self.now();
        for (id, tok) in events {
            if let Some(tx) = self.senders.get(&id) {
                let _ = tx.send(StreamEvent::Token(tok));
            }
            if let Some(lr) = self.live.get_mut(&id) {
                if !lr.streamed {
                    lr.streamed = true;
                    self.ttfs.record(now - lr.submitted_at);
                }
            }
        }
    }

    /// Cancel one live request through the engine, recording a
    /// [`RequestOutcome::Cancelled`].  Returns whether it was live.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        let Some(lr) = self.live.remove(&id) else {
            return false;
        };
        if let Some(resp) = self.engine.cancel(id) {
            self.outcomes.push((lr.tag, RequestOutcome::Cancelled(resp)));
        }
        self.finish_stream(id);
        true
    }

    /// Sleep (wall) or jump (virtual) `dt` seconds forward.
    fn advance(&mut self, dt: f64) {
        match self.cfg.clock {
            ClockMode::Wall => {
                let dt = dt.clamp(0.0, 0.05);
                if dt > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(dt));
                }
            }
            ClockMode::Virtual { .. } => self.vnow += dt.max(0.0),
        }
    }

    /// Offer every due arrival to the engine through the intake gate.
    fn offer(&mut self) {
        let now = self.now();
        while self.arrivals.front().is_some_and(|a| a.at <= now) {
            let arr = self.arrivals.pop_front().expect("front just checked");
            if let Err(reason) = self
                .cfg
                .intake
                .gate(self.engine.queue_len(), self.engine.page_budget())
            {
                if reason == RejectReason::ShedOverload {
                    self.engine.metrics_mut().sheds += 1;
                }
                self.outcomes.push((arr.tag, RequestOutcome::Rejected(reason)));
                continue;
            }
            let prompt_tokens = arr.prompt.len();
            match self.engine.submit(arr.prompt, arr.params) {
                Ok(Some(id)) => {
                    self.recent_prompts.push_back((now, prompt_tokens));
                    self.live.insert(
                        id,
                        LiveRequest { tag: arr.tag, submitted_at: now, streamed: false },
                    );
                    if self.cfg.stream {
                        let (tx, rx) = mpsc::channel();
                        self.senders.insert(id, tx);
                        // tag collision (caller reuse) drops the older
                        // uncollected stream — tags are the caller's
                        // namespace to keep unique
                        self.streams.insert(arr.tag, TokenStream { rx });
                    }
                }
                Ok(None) => {
                    self.outcomes
                        .push((arr.tag, RequestOutcome::Rejected(RejectReason::QueueFull)));
                }
                Err(_) => {
                    self.outcomes.push((
                        arr.tag,
                        RequestOutcome::Rejected(RejectReason::NeverAdmissible),
                    ));
                }
            }
        }
        // Fold the intake window into the adaptive-chunking load signal.
        // Engines without `adaptive_chunking` ignore it, so the call is
        // behaviour-free on the baseline configuration.
        const LOAD_WINDOW_S: f64 = 1.0;
        while self
            .recent_prompts
            .front()
            .is_some_and(|&(t, _)| now - t > LOAD_WINDOW_S)
        {
            self.recent_prompts.pop_front();
        }
        let window_tokens: usize = self.recent_prompts.iter().map(|&(_, n)| n).sum();
        self.engine
            .note_prompt_load(window_tokens as f64 / LOAD_WINDOW_S);
    }

    /// Cancel every live request past its deadline.  The total-latency
    /// budget is checked first (it subsumes TTFT); the TTFT deadline
    /// only fires while the request has produced no token.
    fn expire_deadlines(&mut self) {
        if self.cfg.ttft_deadline_s.is_none() && self.cfg.deadline_s.is_none() {
            return;
        }
        let now = self.now();
        let mut expired: Vec<(RequestId, bool)> = Vec::new();
        for (&id, lr) in &self.live {
            let age = now - lr.submitted_at;
            if self.cfg.deadline_s.is_some_and(|d| age >= d) {
                expired.push((id, false));
            } else if self.cfg.ttft_deadline_s.is_some_and(|d| age >= d)
                && self.engine.awaiting_first_token(id)
            {
                expired.push((id, true));
            }
        }
        // HashMap iteration order is arbitrary — sort so expiry order
        // (and therefore the engine's reclamation order) is
        // deterministic for the chaos runs
        expired.sort();
        for (id, is_ttft) in expired {
            let lr = self.live.remove(&id).expect("collected from live");
            if let Some(resp) = self.engine.cancel(id) {
                self.engine.metrics_mut().deadline_misses += 1;
                let outcome = if is_ttft {
                    RequestOutcome::TtftExpired(resp)
                } else {
                    RequestOutcome::DeadlineExpired(resp)
                };
                self.outcomes.push((lr.tag, outcome));
            }
            self.finish_stream(id);
        }
    }

    /// One front-end step: offer due arrivals, expire deadlines, then
    /// either tick the engine or advance time to the next arrival.
    pub fn step(&mut self) -> FrontendStatus {
        if self.fatal.is_some() {
            return FrontendStatus::Halted;
        }
        self.offer();
        self.expire_deadlines();
        if self.engine.is_idle() {
            let Some(next) = self.arrivals.front() else {
                return FrontendStatus::Done;
            };
            let gap = next.at - self.now();
            match self.cfg.clock {
                ClockMode::Wall => self.advance(gap),
                // jump straight to the arrival; `offer` drained every
                // due arrival above, so `gap > 0` and time advances
                ClockMode::Virtual { .. } => self.vnow += gap.max(0.0),
            }
            return FrontendStatus::Running;
        }
        match self.engine.tick() {
            Ok(responses) => {
                self.attempts = 0;
                self.ticks += 1;
                if let ClockMode::Virtual { tick_s } = self.cfg.clock {
                    self.vnow += tick_s;
                }
                // streams first: a completing request's final token must
                // reach its channel before the End its outcome sends
                self.forward_token_events();
                for resp in responses {
                    let id = resp.id;
                    if let Some(lr) = self.live.remove(&id) {
                        self.outcomes.push((lr.tag, RequestOutcome::Completed(resp)));
                    }
                    self.finish_stream(id);
                }
                FrontendStatus::Running
            }
            Err(e) => self.handle_tick_error(e),
        }
    }

    /// Classify a failed tick: transient → bounded retry with capped
    /// exponential backoff (seeded jitter, see [`RetryPolicy`]);
    /// permanent (or retries exhausted) → abort, drain every admitted
    /// request with a typed outcome, halt.
    fn handle_tick_error(&mut self, e: anyhow::Error) -> FrontendStatus {
        let kind = fault_kind(&e).unwrap_or(FaultKind::Permanent);
        if kind == FaultKind::Transient && self.attempts < self.cfg.retry.max_retries {
            self.attempts += 1;
            self.engine.metrics_mut().retries += 1;
            let backoff = self.cfg.retry.backoff_s(self.attempts);
            log::warn!(
                "frontend: transient tick fault (attempt {}/{}, backing off {:.4}s): {e:#}",
                self.attempts,
                self.cfg.retry.max_retries,
                backoff
            );
            self.advance(backoff);
            return FrontendStatus::Running;
        }
        log::error!("frontend: permanent tick fault, draining: {e:#}");
        self.force_drain(&format!("{e:#}"));
        FrontendStatus::Halted
    }

    /// Halt this front-end as if a permanent fault struck: mark it
    /// fatal, abort every queued and in-flight request into
    /// [`RequestOutcome::Drained`] outcomes, and terminate every open
    /// stream exactly once.  The cluster layer calls this for scripted
    /// replica deaths, then re-offers the drained requests to a healthy
    /// replica (seed-based replay keeps the re-served tokens
    /// bit-identical).  No-op if the front-end already halted.
    pub fn force_drain(&mut self, reason: &str) {
        if self.fatal.is_some() {
            return;
        }
        self.fatal = Some(reason.to_string());
        // the interrupted work committed nothing deliverable — discard
        // any stale events so a halted stream never carries tokens its
        // request's outcome does not
        let _ = self.engine.take_token_events();
        for resp in self.engine.abort_all() {
            let id = resp.id;
            if let Some(lr) = self.live.remove(&id) {
                self.outcomes.push((lr.tag, RequestOutcome::Drained(resp)));
            }
            self.finish_stream(id);
        }
        // halting must still terminate every stream exactly once, even
        // for ids the drain did not surface
        let orphans: Vec<RequestId> = self.senders.keys().copied().collect();
        for id in orphans {
            self.finish_stream(id);
        }
    }

    /// Take ownership of the outcomes recorded since the last call
    /// (the cluster layer harvests these every step so re-offerable
    /// drains never double-count).
    pub fn take_outcomes(&mut self) -> Vec<(u64, RequestOutcome)> {
        std::mem::take(&mut self.outcomes)
    }

    /// Take ownership of every arrival not yet offered to the engine
    /// (the cluster layer reclaims these from a dead replica and
    /// re-routes them).
    pub fn take_unserved(&mut self) -> Vec<ArrivingRequest> {
        self.arrivals.drain(..).collect()
    }

    /// Drive steps until the run completes or halts, then report.
    pub fn run(&mut self) -> ServeReport {
        loop {
            match self.step() {
                FrontendStatus::Running => {}
                FrontendStatus::Done | FrontendStatus::Halted => break,
            }
        }
        self.report()
    }

    /// Fold the outcomes into a [`ServeReport`].
    pub fn report(&self) -> ServeReport {
        let mut rep = ServeReport {
            wall_s: self.now(),
            ticks: self.ticks,
            fatal: self.fatal.clone(),
            unserved: self.arrivals.len() as u64,
            retries: self.engine.metrics().retries,
            ttfs: self.ttfs.clone(),
            ..Default::default()
        };
        for (_, outcome) in &self.outcomes {
            rep.record_outcome(outcome);
        }
        rep
    }
}

#[cfg(test)]
mod tests {
    use super::RetryPolicy;

    /// With jitter off the schedule is pure capped doubling — pin it
    /// exactly (doubling an f64 is exact, so these equalities hold
    /// bit-for-bit on every platform).
    #[test]
    fn backoff_schedule_is_capped_doubling_without_jitter() {
        let p = RetryPolicy {
            max_retries: 8,
            base_backoff_s: 0.001,
            max_backoff_s: 0.004,
            jitter_frac: 0.0,
            seed: 7,
        };
        assert_eq!(p.backoff_s(1), 0.001);
        assert_eq!(p.backoff_s(2), 0.002);
        assert_eq!(p.backoff_s(3), 0.004);
        assert_eq!(p.backoff_s(4), 0.004, "cap holds from here on");
        assert_eq!(p.backoff_s(100), 0.004);
        // attempt 0 is clamped to the first retry
        assert_eq!(p.backoff_s(0), p.backoff_s(1));
    }

    /// Jitter only ever shaves the capped value (never exceeds it,
    /// never shaves more than `jitter_frac`), and the schedule is a
    /// pure function of `(seed, attempt)` — bit-identical on replay.
    #[test]
    fn backoff_jitter_is_bounded_and_seed_deterministic() {
        let p = RetryPolicy { seed: 42, ..RetryPolicy::default() };
        let q = RetryPolicy { seed: 42, ..RetryPolicy::default() };
        for attempt in 1..=10 {
            let b = p.backoff_s(attempt);
            let cap = (p.base_backoff_s * f64::powi(2.0, attempt as i32 - 1))
                .min(p.max_backoff_s);
            assert!(b <= cap, "attempt {attempt}: {b} exceeds capped {cap}");
            assert!(
                b >= cap * (1.0 - p.jitter_frac),
                "attempt {attempt}: {b} shaved below jitter floor"
            );
            assert!(b > 0.0);
            // replay: same seed, same attempt, same bits
            assert_eq!(b.to_bits(), q.backoff_s(attempt).to_bits());
        }
        // a different seed decorrelates the schedule
        let r = RetryPolicy { seed: 43, ..RetryPolicy::default() };
        assert!(
            (1..=10).any(|n| r.backoff_s(n).to_bits() != p.backoff_s(n).to_bits()),
            "seed 43 produced the identical 10-step schedule as seed 42"
        );
    }
}
