//! Intake gate: bounded pending queue, typed rejection, load shedding.
//!
//! The engine's own admission queue rejects on overflow, but it does so
//! with an untyped `false`.  The front-end needs to tell callers *why* a
//! request bounced — a full queue asks for client retry with backoff, an
//! impossible request asks for a smaller prompt, and a shed under
//! overload asks for load to be routed elsewhere.  [`IntakePolicy::gate`]
//! runs before `Engine::submit` and makes that taxonomy explicit.

/// Why the front-end refused a request at intake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The bounded pending queue is full — retry later.
    QueueFull,
    /// The request could never be served (over-long prompt or a
    /// worst-case page need beyond the whole pool) — shrink it.
    NeverAdmissible,
    /// Load shedding: the overload watermark tripped on queue depth or
    /// free-page headroom — route load elsewhere.
    ShedOverload,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full"),
            RejectReason::NeverAdmissible => write!(f, "never admissible"),
            RejectReason::ShedOverload => write!(f, "shed under overload"),
        }
    }
}

/// Backpressure policy applied before a request reaches the engine.
#[derive(Clone, Copy, Debug)]
pub struct IntakePolicy {
    /// Hard cap on the pending queue — at or beyond it, intake rejects
    /// with [`RejectReason::QueueFull`].
    pub max_pending: usize,
    /// Shed watermark on queue depth: at or beyond this many queued
    /// requests, intake sheds before the hard cap is hit.  `None`
    /// disables depth-based shedding.
    pub shed_queue_depth: Option<usize>,
    /// Shed watermark on page headroom: when fewer than this fraction
    /// of usable pages is reclaimable, intake sheds.  `None` disables
    /// page-based shedding (and dense layouts have no page budget).
    pub shed_min_free_frac: Option<f64>,
}

impl Default for IntakePolicy {
    fn default() -> Self {
        IntakePolicy {
            max_pending: 256,
            shed_queue_depth: None,
            shed_min_free_frac: None,
        }
    }
}

impl IntakePolicy {
    /// Gate one arrival given the current queue depth and the paged
    /// layout's `(reclaimable, usable)` page budget (`None` on dense).
    /// `Ok(())` means the request may proceed to `Engine::submit`.
    pub fn gate(
        &self,
        queue_len: usize,
        pages: Option<(usize, usize)>,
    ) -> Result<(), RejectReason> {
        if queue_len >= self.max_pending {
            return Err(RejectReason::QueueFull);
        }
        if self.shed_queue_depth.is_some_and(|d| queue_len >= d) {
            return Err(RejectReason::ShedOverload);
        }
        if let (Some(frac), Some((reclaimable, usable))) = (self.shed_min_free_frac, pages) {
            if (reclaimable as f64) < frac * usable as f64 {
                return Err(RejectReason::ShedOverload);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_only_caps_queue() {
        let p = IntakePolicy::default();
        assert_eq!(p.gate(0, None), Ok(()));
        assert_eq!(p.gate(255, None), Ok(()));
        assert_eq!(p.gate(256, None), Err(RejectReason::QueueFull));
        assert_eq!(p.gate(300, None), Err(RejectReason::QueueFull));
    }

    #[test]
    fn depth_watermark_sheds_before_hard_cap() {
        let p = IntakePolicy {
            max_pending: 16,
            shed_queue_depth: Some(8),
            ..Default::default()
        };
        assert_eq!(p.gate(7, None), Ok(()));
        assert_eq!(p.gate(8, None), Err(RejectReason::ShedOverload));
        // the hard cap still wins when both trip
        assert_eq!(p.gate(16, None), Err(RejectReason::QueueFull));
    }

    #[test]
    fn page_watermark_sheds_on_low_headroom() {
        let p = IntakePolicy {
            shed_min_free_frac: Some(0.25),
            ..Default::default()
        };
        // 30/100 reclaimable: above the 25% watermark
        assert_eq!(p.gate(0, Some((30, 100))), Ok(()));
        // 20/100 reclaimable: below it
        assert_eq!(p.gate(0, Some((20, 100))), Err(RejectReason::ShedOverload));
        // dense layout (no budget): the page watermark is moot
        assert_eq!(p.gate(0, None), Ok(()));
    }

    #[test]
    fn reject_reasons_render() {
        assert_eq!(RejectReason::QueueFull.to_string(), "queue full");
        assert_eq!(RejectReason::ShedOverload.to_string(), "shed under overload");
    }
}
