//! Host-side KV page tier: the second level of the two-tier cache
//! hierarchy.
//!
//! The device pool ([`super::pagetable::PageAllocator`]) is tier 0; this
//! module owns tier 1 — a byte-capped host-side store of KV pages.  It
//! is the **only** code path through which KV page bytes move
//! device↔host: every byte that crosses is booked in [`HostTierStats`]
//! and, on the real engine, mirrored into the runtime's counted
//! transfer machinery (`ExecStats` / `TransferTotals`) under the
//! `"kv_host_tier"` artifact name, so the two ledgers are byte-exact
//! against each other.
//!
//! Pages live in two classes:
//!
//! * **pinned** — a preempted (swapped-out) slot's private pages, keyed
//!   by request id.  Pinned pages are never LRU-evicted: they are owed
//!   back to a live request and only leave through
//!   [`HostTier::unpin`] (re-admission restores them to the device) or
//!   [`HostTier::drop_pin`] (the request was cancelled; the copy is
//!   discarded without a restore transfer).
//! * **free (cached)** — demoted retained-prefix pages, keyed by the
//!   token prefix they hold.  This class is the host-side extension of
//!   the device prefix pool: LRU within the class, evicted silently
//!   under capacity pressure, re-promoted to the device on a prefix
//!   hit.
//!
//! Conservation invariant (audited, and pinned by the chaos suite):
//! `pinned_bytes + cached_bytes + free_bytes == capacity_bytes` — the
//! host ledger's analogue of the device pool's
//! `free + outstanding + retained == usable` partition.
//!
//! The simulator engines move no real bytes; their tier entries carry
//! no payload and the stats count *logical* page bytes
//! (`pages * page_bytes`).  The real engine stages actual KV bytes
//! through the same entries: demotions log a [`HostOp::Demote`] whose
//! device page ids the engine captures (the pool's bytes are intact
//! until the next device write, so draining the op log at the tick's
//! admission boundary is sound), promotions log a [`HostOp::Promote`]
//! carrying the captured payload back for upload.

use std::collections::HashMap;

/// Geometry + capacity of the host tier.  `capacity_bytes == 0`
/// disables the tier entirely (the PR-8 single-tier baseline).
#[derive(Clone, Copy, Debug)]
pub struct HostTierConfig {
    /// Total host bytes the tier may hold (pinned + cached).  Zero
    /// disables the tier.
    pub capacity_bytes: usize,
    /// Bytes one KV page occupies on the host (the device page's K+V
    /// rows across all layers; logical in the simulator).
    pub page_bytes: usize,
}

impl Default for HostTierConfig {
    fn default() -> Self {
        // disabled: single-tier device-only baseline
        HostTierConfig { capacity_bytes: 0, page_bytes: 4096 }
    }
}

/// Byte/page movement counters.  `bytes_to_host` / `bytes_to_device`
/// are the tier's half of the byte-exactness contract with
/// `TransferTotals` (see module docs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HostTierStats {
    /// Bytes moved device → host (swap-outs + demotions).
    pub bytes_to_host: u64,
    /// Bytes moved host → device (swap-ins + promotions).
    pub bytes_to_device: u64,
    /// Pages pinned by preemptive swap-outs.
    pub swapped_out_pages: u64,
    /// Pages restored to the device by swap-ins.
    pub swapped_in_pages: u64,
    /// Prefix pages demoted from the device pool's retained set.
    pub demoted_pages: u64,
    /// Prefix pages re-promoted to the device on a hit.
    pub promoted_pages: u64,
    /// Pinned pages discarded without a restore (cancelled requests).
    pub dropped_pin_pages: u64,
    /// Cached-class pages LRU-evicted under capacity pressure.
    pub evicted_pages: u64,
    /// Prefix pages ingested from *off-node* (a cluster warm-start's
    /// payload arriving over the wire) — host-side arrivals that are
    /// **not** device↔host transfers and therefore book no bytes.
    pub ingested_pages: u64,
}

/// A prefix's KV pages exported off the device — the cluster prefix
/// store's payload.  `bytes` is `None` on the simulator engines (the
/// movement is logical) and `Some` on the real engine, sized
/// `pages * page_bytes`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrefixKv {
    /// The token prefix the pages hold (always a whole number of
    /// pages' worth of rows).
    pub tokens: Vec<i32>,
    /// Full KV pages covered.
    pub pages: usize,
    /// The raw page bytes (real engine only).
    pub bytes: Option<Vec<u8>>,
}

/// One pending real-byte movement for the engine to perform (drained
/// via [`HostTier::take_ops`] at the tick's admission boundary; the
/// simulator drains and discards them).
#[derive(Clone, Debug)]
pub enum HostOp {
    /// Prefix pages left the device for the host: capture these device
    /// pages' KV bytes into the tier entry keyed by `tokens`.
    Demote {
        /// Token prefix keying the tier entry to attach the payload to.
        tokens: Vec<i32>,
        /// Device page ids whose bytes must be captured.
        pages: Vec<u32>,
    },
    /// Prefix pages re-entered the device: write `payload` (captured at
    /// demotion; `None` in the simulator) into these device pages.
    Promote {
        /// Freshly allocated device page ids to write into.
        pages: Vec<u32>,
        /// The KV bytes captured when the entry was demoted.
        payload: Option<Vec<u8>>,
    },
}

#[derive(Clone, Debug)]
struct PinnedEntry {
    pages: usize,
    payload: Option<Vec<u8>>,
}

#[derive(Clone, Debug)]
struct CachedEntry {
    tokens: Vec<i32>,
    pages: usize,
    payload: Option<Vec<u8>>,
    stamp: u64,
}

/// The host tier itself (see module docs).
#[derive(Clone, Debug, Default)]
pub struct HostTier {
    cfg: HostTierConfig,
    clock: u64,
    pins: HashMap<u64, PinnedEntry>,
    cache: Vec<CachedEntry>,
    stats: HostTierStats,
    ops: Vec<HostOp>,
}

impl HostTier {
    /// Tier over `cfg`'s capacity.  A zero capacity builds a disabled
    /// tier: every store/pin refuses, every lookup misses.
    pub fn new(cfg: HostTierConfig) -> Self {
        assert!(cfg.page_bytes > 0, "host tier pages must hold bytes");
        HostTier { cfg, ..Default::default() }
    }

    /// Whether the tier holds any capacity at all.
    pub fn enabled(&self) -> bool {
        self.cfg.capacity_bytes > 0
    }

    /// Host bytes one KV page occupies.
    pub fn page_bytes(&self) -> usize {
        self.cfg.page_bytes
    }

    /// Total capacity in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.cfg.capacity_bytes
    }

    /// Bytes held by the pinned (swapped-out slot) class.
    pub fn pinned_bytes(&self) -> usize {
        self.pins.values().map(|p| p.pages * self.cfg.page_bytes).sum()
    }

    /// Bytes held by the free/cached (demoted prefix) class.
    pub fn cached_bytes(&self) -> usize {
        self.cache.iter().map(|e| e.pages * self.cfg.page_bytes).sum()
    }

    /// Uncommitted capacity: `capacity - pinned - cached`.
    pub fn free_bytes(&self) -> usize {
        self.cfg.capacity_bytes - self.pinned_bytes() - self.cached_bytes()
    }

    /// Movement counters.
    pub fn stats(&self) -> &HostTierStats {
        &self.stats
    }

    /// Drain the pending real-byte operations (engine-side; the
    /// simulator discards them).
    pub fn take_ops(&mut self) -> Vec<HostOp> {
        std::mem::take(&mut self.ops)
    }

    /// Evict cached-class entries (LRU) until at least `need` bytes are
    /// free; returns whether that was achieved.  Pinned entries are
    /// never touched.
    fn evict_cached_until(&mut self, need: usize) -> bool {
        while self.free_bytes() < need {
            let Some(oldest) = self
                .cache
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
            else {
                return false;
            };
            let e = self.cache.swap_remove(oldest);
            self.stats.evicted_pages += e.pages as u64;
        }
        true
    }

    // ---- pinned class: preemptive swap ----

    /// Whether `pages` more pages could be pinned (evicting cached
    /// entries if necessary — only other pins are immovable).
    pub fn can_pin(&self, pages: usize) -> bool {
        self.enabled()
            && pages > 0
            && pages * self.cfg.page_bytes <= self.cfg.capacity_bytes - self.pinned_bytes()
    }

    /// Pin a preempted slot's `pages` pages under `key` (the request
    /// id), evicting cached entries to make room.  `payload` carries
    /// the captured KV bytes on the real engine (`None` in the sim).
    /// Books the device→host transfer.  Returns `false` (tier
    /// untouched) when the pages cannot fit or the key is already
    /// pinned.
    pub fn pin(&mut self, key: u64, pages: usize, payload: Option<Vec<u8>>) -> bool {
        if !self.can_pin(pages) || self.pins.contains_key(&key) {
            return false;
        }
        let need = pages * self.cfg.page_bytes;
        if !self.evict_cached_until(need) {
            return false;
        }
        self.pins.insert(key, PinnedEntry { pages, payload });
        self.stats.bytes_to_host += need as u64;
        self.stats.swapped_out_pages += pages as u64;
        true
    }

    /// Pages pinned under `key`, if any.
    pub fn pinned(&self, key: u64) -> Option<usize> {
        self.pins.get(&key).map(|p| p.pages)
    }

    /// Release `key`'s pin for re-admission: the pages re-enter the
    /// device, booking the host→device transfer.  Returns the page
    /// count and the captured payload.
    pub fn unpin(&mut self, key: u64) -> Option<(usize, Option<Vec<u8>>)> {
        let e = self.pins.remove(&key)?;
        self.stats.bytes_to_device += (e.pages * self.cfg.page_bytes) as u64;
        self.stats.swapped_in_pages += e.pages as u64;
        Some((e.pages, e.payload))
    }

    /// Discard `key`'s pin without a restore (the request was cancelled
    /// or drained while swapped out): no device transfer happens.
    pub fn drop_pin(&mut self, key: u64) -> Option<usize> {
        let e = self.pins.remove(&key)?;
        self.stats.dropped_pin_pages += e.pages as u64;
        Some(e.pages)
    }

    /// Discard every pin (engine drain).  Returns the pages dropped.
    pub fn drop_all_pins(&mut self) -> usize {
        let keys: Vec<u64> = self.pins.keys().copied().collect();
        keys.iter().filter_map(|&k| self.drop_pin(k)).sum()
    }

    // ---- cached class: demoted prefix pages ----

    /// Demote a retained prefix entry to the host: `tokens` is the full
    /// token prefix the entry covers, `device_pages` the device page
    /// ids being vacated (their count prices the transfer; their ids
    /// go on the op log for the engine's byte capture).  Refuses (and
    /// books nothing) when the tier is disabled, the entry is already
    /// covered by a cached entry, or even evicting every cached entry
    /// could not fit it.
    pub fn store_prefix(&mut self, tokens: &[i32], device_pages: &[u32]) -> bool {
        if !self.ingest_prefix(tokens, device_pages.len(), None, true) {
            return false;
        }
        self.ops.push(HostOp::Demote {
            tokens: tokens.to_vec(),
            pages: device_pages.to_vec(),
        });
        true
    }

    /// Insert a cached-class entry without logging a capture op: the
    /// staging path for exports (the engine captures bytes inline;
    /// `from_device: true` books the device→host transfer) and for
    /// cluster warm-starts whose payload arrived over the wire
    /// (`from_device: false` — a host-side arrival, no device transfer
    /// to book).  Same refusal/eviction rules as [`Self::store_prefix`].
    pub fn ingest_prefix(
        &mut self,
        tokens: &[i32],
        pages: usize,
        payload: Option<Vec<u8>>,
        from_device: bool,
    ) -> bool {
        if !self.enabled() || pages == 0 || tokens.is_empty() {
            return false;
        }
        if self
            .cache
            .iter()
            .any(|e| e.tokens.len() >= tokens.len() && e.tokens.starts_with(tokens))
        {
            return false; // already covered — no bytes need to move
        }
        let need = pages * self.cfg.page_bytes;
        if need > self.cfg.capacity_bytes - self.pinned_bytes() {
            return false;
        }
        // a shorter entry this one extends is superseded: drop it first
        // so the class never holds nested duplicates of one prefix
        self.cache.retain(|e| !tokens.starts_with(&e.tokens));
        if !self.evict_cached_until(need) {
            return false;
        }
        self.clock += 1;
        self.cache.push(CachedEntry {
            tokens: tokens.to_vec(),
            pages,
            payload,
            stamp: self.clock,
        });
        if from_device {
            self.stats.bytes_to_host += need as u64;
            self.stats.demoted_pages += pages as u64;
        } else {
            self.stats.ingested_pages += pages as u64;
        }
        true
    }

    /// Clone the best cached entry for `prompt` without promoting or
    /// removing it (the export path re-serves an already-staged copy:
    /// host → wire is the store's concern, no device transfer books).
    pub fn clone_prefix(&self, prompt: &[i32]) -> Option<(Vec<i32>, usize, Option<Vec<u8>>)> {
        let i = self.best_prefix(prompt)?;
        let e = &self.cache[i];
        Some((e.tokens.clone(), e.pages, e.payload.clone()))
    }

    /// Attach the real KV bytes captured for a demoted entry (engine
    /// op-drain path).  Returns whether the entry still exists.
    pub fn attach_prefix_payload(&mut self, tokens: &[i32], payload: Vec<u8>) -> bool {
        if let Some(e) = self.cache.iter_mut().find(|e| e.tokens == tokens) {
            e.payload = Some(payload);
            return true;
        }
        false
    }

    /// Best cached entry for `prompt` without promoting it: the page
    /// count of the longest cached token prefix of `prompt`.
    pub fn peek_prefix(&self, prompt: &[i32]) -> Option<usize> {
        self.best_prefix(prompt).map(|i| self.cache[i].pages)
    }

    fn best_prefix(&self, prompt: &[i32]) -> Option<usize> {
        self.cache
            .iter()
            .enumerate()
            .filter(|(_, e)| prompt.starts_with(&e.tokens))
            .max_by_key(|(_, e)| (e.pages, e.stamp))
            .map(|(i, _)| i)
    }

    /// Promote the best cached entry for `prompt` back to the device:
    /// removes it, books the host→device transfer, and logs the
    /// [`HostOp::Promote`] writing its payload into `device_pages`
    /// (the fresh pages the caller allocated for it).  `None` on miss.
    /// `device_pages.len()` must equal the entry's page count — the
    /// caller sizes the allocation from [`Self::peek_prefix`].
    pub fn take_prefix(
        &mut self,
        prompt: &[i32],
        device_pages: &[u32],
    ) -> Option<(Vec<i32>, usize)> {
        let idx = self.best_prefix(prompt)?;
        let e = self.cache.swap_remove(idx);
        assert_eq!(
            device_pages.len(),
            e.pages,
            "promotion allocation does not match the demoted entry"
        );
        self.stats.bytes_to_device += (e.pages * self.cfg.page_bytes) as u64;
        self.stats.promoted_pages += e.pages as u64;
        self.ops.push(HostOp::Promote {
            pages: device_pages.to_vec(),
            payload: e.payload,
        });
        Some((e.tokens, e.pages))
    }

    /// Conservation + structure audit; panics on the first violation.
    /// `pinned + cached + free == capacity` holds by construction of
    /// [`Self::free_bytes`]; this re-derives both classes from the
    /// entries and checks the capacity bound and payload sizing.
    pub fn audit(&self) {
        let pinned = self.pinned_bytes();
        let cached = self.cached_bytes();
        assert!(
            pinned + cached <= self.cfg.capacity_bytes,
            "host tier overfull: pinned {pinned} + cached {cached} > cap {}",
            self.cfg.capacity_bytes
        );
        assert_eq!(
            pinned + cached + self.free_bytes(),
            self.cfg.capacity_bytes,
            "host tier partition broken"
        );
        for e in &self.cache {
            assert!(e.pages > 0, "empty cached entry");
            assert!(!e.tokens.is_empty(), "cached entry holds no tokens");
            if let Some(p) = &e.payload {
                assert_eq!(
                    p.len(),
                    e.pages * self.cfg.page_bytes,
                    "cached payload does not span its pages"
                );
            }
        }
        for (k, p) in &self.pins {
            assert!(p.pages > 0, "empty pin under key {k}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tier(cap_pages: usize) -> HostTier {
        HostTier::new(HostTierConfig { capacity_bytes: cap_pages * 64, page_bytes: 64 })
    }

    #[test]
    fn disabled_tier_refuses_everything() {
        let mut t = HostTier::new(HostTierConfig::default());
        assert!(!t.enabled());
        assert!(!t.pin(1, 2, None));
        assert!(!t.store_prefix(&[1, 2, 3], &[4]));
        assert!(t.peek_prefix(&[1, 2, 3]).is_none());
        assert_eq!(t.stats(), &HostTierStats::default());
        t.audit();
    }

    #[test]
    fn pin_unpin_round_trip_books_bytes_both_ways() {
        let mut t = tier(8);
        assert!(t.pin(7, 3, None));
        assert_eq!(t.pinned(7), Some(3));
        assert_eq!(t.pinned_bytes(), 3 * 64);
        assert_eq!(t.free_bytes(), 5 * 64);
        let (pages, payload) = t.unpin(7).expect("pinned");
        assert_eq!((pages, payload), (3, None));
        assert_eq!(t.pinned_bytes(), 0);
        assert_eq!(t.stats().bytes_to_host, 3 * 64);
        assert_eq!(t.stats().bytes_to_device, 3 * 64);
        assert_eq!(t.stats().swapped_out_pages, 3);
        assert_eq!(t.stats().swapped_in_pages, 3);
        t.audit();
    }

    #[test]
    fn dropped_pins_move_no_bytes_back() {
        let mut t = tier(4);
        assert!(t.pin(1, 2, Some(vec![0u8; 2 * 64])));
        assert_eq!(t.drop_pin(1), Some(2));
        assert_eq!(t.stats().bytes_to_device, 0, "discard is not a restore");
        assert_eq!(t.stats().dropped_pin_pages, 2);
        assert_eq!(t.drop_pin(1), None, "double drop is clean");
        t.audit();
    }

    #[test]
    fn pins_never_exceed_capacity_and_never_evict_pins() {
        let mut t = tier(4);
        assert!(t.pin(1, 3, None));
        assert!(!t.can_pin(2), "only 1 page of headroom");
        assert!(!t.pin(2, 2, None), "refused, tier untouched");
        assert!(t.pin(2, 1, None));
        assert_eq!(t.free_bytes(), 0);
        t.audit();
    }

    #[test]
    fn demoted_prefixes_promote_back_with_lru_eviction() {
        let mut t = tier(4);
        assert!(t.store_prefix(&[1, 2], &[5, 6]));
        assert!(t.store_prefix(&[9, 9], &[7, 8]));
        assert_eq!(t.free_bytes(), 0);
        // a third entry evicts the LRU ([1,2])
        assert!(t.store_prefix(&[4, 4], &[9, 10]));
        assert_eq!(t.stats().evicted_pages, 2);
        assert!(t.peek_prefix(&[1, 2, 3]).is_none(), "evicted");
        assert_eq!(t.peek_prefix(&[9, 9, 1]), Some(2));
        // promotion removes the entry and books the restore
        let fresh = [11u32, 12u32];
        let (tokens, pages) = t.take_prefix(&[9, 9, 1], &fresh).expect("hit");
        assert_eq!((tokens.as_slice(), pages), (&[9, 9][..], 2));
        assert!(t.peek_prefix(&[9, 9, 1]).is_none(), "promoted out");
        assert_eq!(t.stats().promoted_pages, 2);
        assert_eq!(t.stats().bytes_to_device, 2 * 64);
        t.audit();
    }

    #[test]
    fn covered_and_superseding_prefixes_dedup() {
        let mut t = tier(8);
        assert!(t.store_prefix(&[1, 2, 3, 4], &[5, 6]));
        assert!(
            !t.store_prefix(&[1, 2], &[7]),
            "shorter prefix already covered — no bytes move"
        );
        // a longer prefix supersedes the shorter entry
        assert!(t.store_prefix(&[1, 2, 3, 4, 5, 6], &[5, 6, 7]));
        assert_eq!(t.cached_bytes(), 3 * 64, "one entry, not nested copies");
        assert_eq!(t.peek_prefix(&[1, 2, 3, 4, 5, 6, 9]), Some(3));
        t.audit();
    }

    #[test]
    fn ops_log_carries_demote_then_promote_for_engine_capture() {
        let mut t = tier(4);
        assert!(t.store_prefix(&[1, 2], &[5, 6]));
        assert!(t.attach_prefix_payload(&[1, 2], vec![7u8; 2 * 64]));
        let ops = t.take_ops();
        assert!(matches!(
            ops.as_slice(),
            [HostOp::Demote { tokens, pages }] if tokens == &[1, 2] && pages == &[5, 6]
        ));
        let (_, _) = t.take_prefix(&[1, 2, 9], &[8, 9]).expect("hit");
        let ops = t.take_ops();
        match ops.as_slice() {
            [HostOp::Promote { pages, payload }] => {
                assert_eq!(pages, &[8, 9]);
                assert_eq!(payload.as_ref().map(|p| p.len()), Some(2 * 64));
            }
            other => panic!("unexpected ops {other:?}"),
        }
        t.audit();
    }

    #[test]
    fn wire_ingest_books_no_device_transfer_and_clones_back() {
        let mut t = tier(4);
        // a warm-start payload arrives over the wire: host-side only
        assert!(t.ingest_prefix(&[1, 2], 2, Some(vec![9u8; 2 * 64]), false));
        assert_eq!(t.stats().bytes_to_host, 0, "wire arrival is not a device move");
        assert_eq!(t.stats().ingested_pages, 2);
        // the export path re-serves the staged copy without promotion
        let (tokens, pages, payload) = t.clone_prefix(&[1, 2, 3]).expect("staged");
        assert_eq!((tokens.as_slice(), pages), (&[1, 2][..], 2));
        assert_eq!(payload.map(|p| p.len()), Some(2 * 64));
        assert_eq!(t.peek_prefix(&[1, 2, 3]), Some(2), "clone does not consume");
        assert!(t.take_ops().is_empty(), "no engine capture needed");
        t.audit();
    }

    #[test]
    fn conservation_identity_holds_across_mixed_traffic() {
        let mut t = tier(6);
        assert!(t.pin(1, 2, None));
        assert!(t.store_prefix(&[3, 3, 3], &[4, 5, 6]));
        assert_eq!(
            t.pinned_bytes() + t.cached_bytes() + t.free_bytes(),
            t.capacity_bytes(),
            "pinned + cached + free == cap"
        );
        // pinning under pressure evicts cached, never pins
        assert!(t.pin(2, 3, None));
        assert_eq!(t.pinned_bytes(), 5 * 64);
        assert_eq!(t.cached_bytes(), 0, "cached class gave way");
        assert_eq!(t.stats().evicted_pages, 3);
        assert_eq!(
            t.pinned_bytes() + t.cached_bytes() + t.free_bytes(),
            t.capacity_bytes()
        );
        t.audit();
    }
}
