//! Refcounted free-list page allocator + reservation ledger for the
//! paged KV cache.
//!
//! The paged serving layout stores KV rows in fixed-size pages shared by
//! every decode slot (pools of shape `(L, num_pages, page_size, nh, dh)`
//! on device); this allocator owns the *page ids*.  Two admission
//! policies sit on top of it (selected by the engine):
//!
//! * **Eager** (PR 3): a slot's full worst-case need
//!   (`ceil((prompt + max_new) / page_size)` pages) is allocated at
//!   admission via [`PageAllocator::admit`]`(need, 0)` and released at
//!   retirement — simple, but memory savings stop at the
//!   typical-vs-worst-case context ratio.
//! * **Lazy growth**: admission allocates only the pages the prompt
//!   needs plus one decode page, and *reserves* the rest of the
//!   worst-case need in the ledger ([`PageAllocator::admit`]`(fresh,
//!   reserve)`).  As the slot's `pos` crosses page boundaries the engine
//!   converts one reservation into one real page with
//!   [`PageAllocator::grow_reserved`].  Admission gates on *unreserved*
//!   pages, so a grow request is always satisfiable from reserved
//!   headroom — lazy growth can never deadlock (`free >= reserved` is a
//!   structural invariant, asserted on every mutation).
//! * **Overcommitted lazy growth** (PR 9): with an overcommit factor
//!   `f > 1` ([`PageAllocator::set_overcommit`]) the admission gate
//!   relaxes to `fresh + reserve <= floor(free * f) - reserved` —
//!   `reserved` may exceed `free`, trading the deadlock-freedom
//!   invariant for admitted width.  Growth can then genuinely run dry
//!   ([`PageAllocator::try_grow_reserved`] returns `None`); the
//!   coordinator must preempt a victim slot (swapping its pages to the
//!   host tier, see `kvcache::host_tier`) to refill the free list
//!   before converting the reservation.  At `f = 1.0` every gate and
//!   assert reduces bit-identically to the strict ledger.
//!
//! Pages are **refcounted** so prompt-prefix pages can be shared
//! copy-on-write across block tables: an admission that shares a
//! donor's prefix pages [`PageAllocator::retain`]s them instead of
//! allocating fresh ones; [`PageAllocator::release`] returns a page to
//! the free list only when its last reference drops.  Shared pages are
//! never written (the engine gives every slot a private page for any
//! position it will decode into — see `coordinator/engine.rs`), so
//! sharing needs no device-side copy.
//!
//! Pages can also be **parked**: the retained prefix pool
//! (`kvcache::prefix_pool`) adopts a retiring slot's reference on its
//! prompt-prefix pages via [`PageAllocator::park`] instead of letting
//! them free, so a hot system prompt's KV survives idle gaps between
//! requests.  A parked page whose only reference is the pool's is
//! *retained*: not free (it must not be re-handed out — its contents
//! are live cache state), not outstanding (no block table references
//! it), and reclaimable on demand through [`PageAllocator::evict`]
//! when admission would otherwise starve.  A parked page with live
//! block-table references on top of the pool's is ordinary outstanding
//! state and can never be evicted.
//!
//! **Page 0 is reserved** as the garbage page: the lowered artifacts
//! route every inactive slot's scatter traffic and every sentinel
//! block-table entry there, so it must never be handed out.
//!
//! Invariants (unit-tested below, exercised end-to-end by the
//! integration tests, `prop_prefix_pool_conservation`, and the Python
//! protocol twin):
//! * conservation: `free_pages() + outstanding() + retained_pages()
//!   == usable_pages()` — a page is outstanding iff some block table
//!   references it (shared pages count once, however many tables), and
//!   retained iff the prefix pool holds its only reference;
//! * deadlock freedom: `free_pages() >= reserved_pages()` always, so a
//!   slot holding reservations can always grow;
//! * no double-allocation: a free page has refcount 0, an allocated
//!   page's id appears in no free list;
//! * no live eviction: [`PageAllocator::evict`] refuses any page with a
//!   block-table reference (refcount above the pool's own);
//! * exhaustion is a clean `None` (the caller queues the admission),
//!   never a partial allocation.

/// The reserved garbage page id (see module docs).
pub const RESERVED_PAGE: u32 = 0;

/// Refcounted free-list allocator over the pool's page ids, with a
/// reservation ledger for deadlock-free lazy growth.
#[derive(Clone, Debug)]
pub struct PageAllocator {
    /// Pages available for allocation (stack: last freed, first reused).
    free: Vec<u32>,
    /// Per-page reference count (0 = free; the reserved page is pinned).
    refs: Vec<u32>,
    /// Per-page "the retained prefix pool holds one of this page's
    /// references" flag ([`Self::park`] / [`Self::evict`]).
    parked: Vec<bool>,
    /// Parked pages whose ONLY reference is the pool's (the evictable
    /// retained set; maintained incrementally at every transition).
    retained: usize,
    /// Pages promised to in-flight slots for future growth; kept on the
    /// free list but excluded from admission ([`Self::unreserved_pages`]).
    reserved: usize,
    /// Reservation-ledger overcommit factor (`>= 1.0`; `1.0` = strict
    /// deadlock-free ledger).  See the module docs' overcommit bullet.
    overcommit: f64,
    /// Total pages in the pool, including the reserved page.
    num_pages: usize,
    /// Rows per page.
    page_size: usize,
}

impl PageAllocator {
    /// Allocator over `num_pages` pool pages of `page_size` rows each;
    /// page [`RESERVED_PAGE`] is held back as the garbage page.
    pub fn new(num_pages: usize, page_size: usize) -> Self {
        assert!(num_pages > 1, "pool must hold the reserved page plus data");
        assert!(page_size > 0, "pages must hold at least one row");
        // ascending ids pop from the high end; deterministic either way
        let free: Vec<u32> = (1..num_pages as u32).collect();
        let mut refs = vec![0u32; num_pages];
        refs[RESERVED_PAGE as usize] = 1; // never handed out
        PageAllocator {
            free,
            refs,
            parked: vec![false; num_pages],
            retained: 0,
            reserved: 0,
            overcommit: 1.0,
            num_pages,
            page_size,
        }
    }

    /// Set the reservation-ledger overcommit factor (`>= 1.0`).  At
    /// `1.0` the allocator is the strict deadlock-free ledger; above it
    /// `reserved` may exceed `free` up to the factor and growth can run
    /// dry (see [`Self::try_grow_reserved`]).
    pub fn set_overcommit(&mut self, factor: f64) {
        assert!(
            factor >= 1.0 && factor.is_finite(),
            "overcommit factor must be a finite value >= 1.0, got {factor}"
        );
        self.overcommit = factor;
    }

    /// The configured reservation-ledger overcommit factor.
    pub fn overcommit(&self) -> f64 {
        self.overcommit
    }

    /// Rows per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Total pages in the pool (including the reserved page).
    pub fn num_pages(&self) -> usize {
        self.num_pages
    }

    /// Pages that can ever be allocated (`num_pages - 1`).
    pub fn usable_pages(&self) -> usize {
        self.num_pages - 1
    }

    /// Pages currently on the free list (including reserved headroom).
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Free pages promised to in-flight slots for lazy growth.
    pub fn reserved_pages(&self) -> usize {
        self.reserved
    }

    /// Free pages available to *new* admissions under the **strict**
    /// ledger: the free list minus the growth headroom reserved by
    /// in-flight slots (saturating — under overcommit `reserved` may
    /// legitimately exceed `free`).  Warm-page preloads gate on this
    /// even when admission overcommits: parked prefix state must never
    /// consume promised growth headroom.
    pub fn unreserved_pages(&self) -> usize {
        debug_assert!(
            self.overcommit > 1.0 || self.free.len() >= self.reserved,
            "reservation ledger corrupt"
        );
        self.free.len().saturating_sub(self.reserved)
    }

    /// Pages available to *new* admissions under the configured
    /// overcommit factor: `floor(free * f) - reserved` (saturating).
    /// At `f = 1.0` this is exactly [`Self::unreserved_pages`] — the
    /// gate arithmetic is bit-identical to the strict ledger.
    pub fn admission_budget(&self) -> usize {
        let inflated = (self.free.len() as f64 * self.overcommit).floor() as usize;
        inflated.saturating_sub(self.reserved)
    }

    /// Pages currently held by at least one slot (refcount ≥ 1 beyond
    /// any prefix-pool reference; a page shared by several block tables
    /// counts once).  Together with [`Self::free_pages`] and
    /// [`Self::retained_pages`] this partitions the usable pool.
    pub fn outstanding(&self) -> usize {
        self.usable_pages() - self.free.len() - self.retained
    }

    /// Parked pages whose only reference is the retained prefix pool's
    /// (the evictable retained set).
    pub fn retained_pages(&self) -> usize {
        self.retained
    }

    /// Reference count of one page (0 = free).
    pub fn refcount(&self, page: u32) -> u32 {
        self.refs[page as usize]
    }

    /// Pages needed to hold `rows` KV rows (`ceil(rows / page_size)`).
    pub fn pages_for(&self, rows: usize) -> usize {
        rows.div_ceil(self.page_size)
    }

    /// Admit one slot: allocate `fresh` pages now and reserve `reserve`
    /// more for its future growth, or `None` (state untouched) if the
    /// *unreserved* pool cannot cover `fresh + reserve` — exhaustion is
    /// the caller's queue-or-reject signal.  Eager admission is
    /// `admit(worst_case, 0)`; lazy admission is `admit(initial,
    /// worst_case - initial - shared)`.
    pub fn admit(&mut self, fresh: usize, reserve: usize) -> Option<Vec<u32>> {
        if fresh + reserve > self.admission_budget() {
            return None;
        }
        // only *reservations* overcommit — fresh pages must exist now
        if fresh > self.free.len() {
            return None;
        }
        let pages = self.free.split_off(self.free.len() - fresh);
        for &p in &pages {
            debug_assert_eq!(self.refs[p as usize], 0, "double allocation");
            self.refs[p as usize] = 1;
        }
        self.reserved += reserve;
        Some(pages)
    }

    /// Allocate `n` pages with no reservation (eager policy shorthand).
    pub fn alloc(&mut self, n: usize) -> Option<Vec<u32>> {
        self.admit(n, 0)
    }

    /// Convert one of the caller's reservations into a real page (lazy
    /// growth when a slot's `pos` crosses a page boundary).  Always
    /// succeeds when the caller holds a reservation — `free >= reserved
    /// >= 1` is the ledger invariant.
    ///
    /// Panics if no reservations exist at all: growing without a
    /// reservation is a coordinator bug that could deadlock admission.
    /// Panics when growth runs dry — under the strict ledger that is a
    /// corrupt ledger; under overcommit the coordinator must check
    /// [`Self::try_grow_reserved`] (or preempt first) instead.
    pub fn grow_reserved(&mut self) -> u32 {
        assert!(self.reserved > 0, "grow without a reservation");
        assert!(!self.free.is_empty(), "reservation ledger corrupt: no free page");
        self.reserved -= 1;
        let p = self.free.pop().expect("checked non-empty");
        debug_assert_eq!(self.refs[p as usize], 0, "double allocation");
        self.refs[p as usize] = 1;
        p
    }

    /// [`Self::grow_reserved`] that reports dry growth instead of
    /// panicking: `None` when the caller holds a reservation but the
    /// free list is empty — the overcommitted ledger's preemption
    /// signal.  Still panics when no reservation exists at all (that is
    /// a coordinator bug under every policy).
    pub fn try_grow_reserved(&mut self) -> Option<u32> {
        assert!(self.reserved > 0, "grow without a reservation");
        if self.free.is_empty() {
            return None;
        }
        Some(self.grow_reserved())
    }

    /// Return `n` reservations to the unreserved pool (slot retired or
    /// aborted before exhausting its growth budget).
    pub fn unreserve(&mut self, n: usize) {
        assert!(n <= self.reserved, "unreserve of {n} exceeds ledger {}", self.reserved);
        self.reserved -= n;
    }

    /// Add one reference to an allocated page (prompt-prefix sharing:
    /// the new slot's block table points at the donor's — or the
    /// retained prefix pool's — page).  Re-sharing a retained page
    /// moves it back to outstanding.
    ///
    /// Panics on the reserved page or a free page — sharing garbage or
    /// an unowned page would corrupt another slot's KV state.
    pub fn retain(&mut self, page: u32) {
        assert_ne!(page, RESERVED_PAGE, "retained the reserved garbage page");
        let p = page as usize;
        assert!(
            p < self.num_pages && self.refs[p] > 0,
            "retain of free page {page}"
        );
        if self.parked[p] && self.refs[p] == 1 {
            // the pool's ref was the only one: retained -> outstanding
            self.retained -= 1;
        }
        self.refs[p] += 1;
    }

    /// Drop one reference to a page; it returns to the free list when
    /// the last reference goes (slot retirement / abort).  A *parked*
    /// page never reaches the free list this way: when its last
    /// block-table reference drops it becomes retained (the pool's own
    /// reference only leaves through [`Self::evict`]).
    ///
    /// Panics on over-release or on releasing the reserved page — both
    /// are coordinator bugs that would silently corrupt another slot's
    /// KV state if let through.
    pub fn release(&mut self, page: u32) {
        assert_ne!(page, RESERVED_PAGE, "freed the reserved garbage page");
        let p = page as usize;
        assert!(
            p < self.num_pages && self.refs[p] > 0,
            "double free of page {page}"
        );
        if self.parked[p] {
            assert!(
                self.refs[p] > 1,
                "released the prefix pool's own reference to page {page} \
                 (parked pages leave through evict)"
            );
            self.refs[p] -= 1;
            if self.refs[p] == 1 {
                // last block-table ref gone: outstanding -> retained
                self.retained += 1;
            }
            return;
        }
        self.refs[p] -= 1;
        if self.refs[p] == 0 {
            self.free.push(page);
        }
    }

    /// The retained prefix pool adopts the caller's reference to an
    /// allocated page (slot retirement parking its prompt-prefix
    /// pages): no refcount change — ownership of one existing reference
    /// transfers to the pool — but the page can no longer free through
    /// [`Self::release`].
    ///
    /// Panics on the reserved page, a free page, or a page the pool
    /// already owns (two index entries claiming one page would
    /// double-account eviction).
    pub fn park(&mut self, page: u32) {
        assert_ne!(page, RESERVED_PAGE, "parked the reserved garbage page");
        let p = page as usize;
        assert!(
            p < self.num_pages && self.refs[p] > 0,
            "park of free page {page}"
        );
        assert!(!self.parked[p], "page {page} parked twice");
        self.parked[p] = true;
        if self.refs[p] == 1 {
            self.retained += 1;
        }
    }

    /// Evict one *retained* page: the prefix pool drops its reference
    /// and the page returns to the free list (LRU reclamation when
    /// admission would otherwise starve).
    ///
    /// Panics unless the page is parked with the pool's reference as
    /// its only one — evicting a page a live block table still points
    /// at would corrupt that slot's KV state mid-flight.
    pub fn evict(&mut self, page: u32) {
        let p = page as usize;
        assert!(
            p < self.num_pages && self.parked[p],
            "evict of unparked page {page}"
        );
        assert_eq!(
            self.refs[p], 1,
            "evict of page {page} with live block-table references"
        );
        self.parked[p] = false;
        self.refs[p] = 0;
        self.retained -= 1;
        self.free.push(page);
    }

    /// Release a whole block table (slot retirement).  Shared pages only
    /// actually free once their last referencing table is released.
    pub fn free(&mut self, pages: Vec<u32>) {
        for p in pages {
            self.release(p);
        }
    }

    /// Full-scan consistency check, used by the property tests after
    /// every mutation: the free list holds exactly the refcount-0
    /// unparked pages (no duplicates), parked pages are referenced, the
    /// retained counter matches its definition, the free/outstanding/
    /// retained partition conserves the pool, and the reservation
    /// ledger never overcommits the free list.  Panics with the first
    /// violation found.
    pub fn audit(&self) {
        assert_eq!(self.refs.len(), self.num_pages);
        assert_eq!(self.parked.len(), self.num_pages);
        assert!(self.refs[RESERVED_PAGE as usize] >= 1, "garbage page unpinned");
        assert!(!self.parked[RESERVED_PAGE as usize], "garbage page parked");
        let mut on_free = vec![false; self.num_pages];
        for &p in &self.free {
            let p = p as usize;
            assert!(p != RESERVED_PAGE as usize && p < self.num_pages);
            assert!(!on_free[p], "page {p} on the free list twice");
            on_free[p] = true;
            assert_eq!(self.refs[p], 0, "free page {p} has references");
            assert!(!self.parked[p], "free page {p} is parked");
        }
        let mut retained = 0usize;
        for p in 1..self.num_pages {
            if self.parked[p] {
                assert!(self.refs[p] >= 1, "parked page {p} unreferenced");
                if self.refs[p] == 1 {
                    retained += 1;
                }
            }
            assert!(
                on_free[p] || self.refs[p] >= 1,
                "page {p} neither free nor referenced (leaked)"
            );
        }
        assert_eq!(retained, self.retained, "retained counter drifted");
        assert_eq!(
            self.free_pages() + self.outstanding() + self.retained_pages(),
            self.usable_pages(),
            "free/outstanding/retained partition broken"
        );
        if self.overcommit <= 1.0 {
            assert!(
                self.free_pages() >= self.reserved_pages(),
                "reservation ledger overcommits the free list"
            );
        } else {
            // the overcommitted ledger is bounded by the factor over the
            // whole usable pool (the admission-time gate is tighter; this
            // is the coarse structural backstop)
            let cap = (self.usable_pages() as f64 * self.overcommit).floor() as usize;
            assert!(
                self.reserved_pages() <= cap,
                "reservation ledger exceeds the overcommit cap: {} > {cap}",
                self.reserved_pages()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conservation_over_alloc_free_round_trips() {
        let mut a = PageAllocator::new(17, 16);
        assert_eq!(a.usable_pages(), 16);
        assert_eq!(a.free_pages(), 16);
        let p1 = a.alloc(5).unwrap();
        let p2 = a.alloc(7).unwrap();
        assert_eq!(a.free_pages() + a.outstanding(), a.usable_pages());
        assert_eq!(a.outstanding(), 12);
        a.free(p1);
        assert_eq!(a.free_pages(), 9);
        a.free(p2);
        assert_eq!(a.free_pages(), 16);
        assert_eq!(a.outstanding(), 0);
    }

    #[test]
    fn never_hands_out_the_reserved_page_or_duplicates() {
        let mut a = PageAllocator::new(9, 4);
        let mut seen = std::collections::HashSet::new();
        let pages = a.alloc(8).unwrap();
        for p in pages {
            assert_ne!(p, RESERVED_PAGE, "reserved page allocated");
            assert!(seen.insert(p), "page {p} allocated twice");
        }
        assert_eq!(seen.len(), 8);
    }

    #[test]
    fn exhaustion_returns_none_and_preserves_state() {
        let mut a = PageAllocator::new(5, 4);
        let held = a.alloc(3).unwrap();
        assert!(a.alloc(2).is_none(), "only 1 page left");
        assert_eq!(a.free_pages(), 1, "failed alloc must not consume pages");
        assert!(a.alloc(1).is_some());
        a.free(held);
        assert_eq!(a.free_pages(), 3);
    }

    #[test]
    fn freed_pages_are_reused_without_growth() {
        let mut a = PageAllocator::new(4, 8);
        for _ in 0..100 {
            let p = a.alloc(3).unwrap();
            a.free(p);
        }
        assert_eq!(a.free_pages(), 3);
        assert_eq!(a.outstanding(), 0);
    }

    #[test]
    fn pages_for_rounds_up() {
        let a = PageAllocator::new(8, 16);
        assert_eq!(a.pages_for(1), 1);
        assert_eq!(a.pages_for(16), 1);
        assert_eq!(a.pages_for(17), 2);
        assert_eq!(a.pages_for(160), 10);
        assert_eq!(a.pages_for(0), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut a = PageAllocator::new(4, 4);
        let p = a.alloc(1).unwrap();
        a.free(p.clone());
        a.free(p);
    }

    // ---- reservation ledger (lazy growth) ----

    #[test]
    fn reservations_gate_admission_but_not_the_free_list() {
        let mut a = PageAllocator::new(11, 4); // 10 usable
        let t = a.admit(2, 5).unwrap(); // lazy slot: 2 now, 5 promised
        assert_eq!(t.len(), 2);
        assert_eq!(a.free_pages(), 8, "reserved pages stay on the free list");
        assert_eq!(a.reserved_pages(), 5);
        assert_eq!(a.unreserved_pages(), 3);
        // a worst-case-4 admission no longer fits, even though 8 are free
        assert!(a.admit(4, 0).is_none(), "admission must gate on unreserved");
        assert!(a.admit(2, 1).is_some(), "but the unreserved prefix fits");
        a.free(t);
        a.unreserve(5);
        assert_eq!(a.unreserved_pages(), a.free_pages());
    }

    #[test]
    fn growth_is_always_satisfiable_from_reservations() {
        // the deadlock-freedom invariant: free >= reserved, so every
        // reservation can be converted even under total admission
        // starvation
        let mut a = PageAllocator::new(9, 4); // 8 usable
        let s1 = a.admit(1, 3).unwrap();
        let s2 = a.admit(1, 3).unwrap();
        assert_eq!(a.unreserved_pages(), 0, "pool fully committed");
        assert!(a.admit(1, 0).is_none(), "no admission under full commitment");
        let mut t1 = s1;
        let mut t2 = s2;
        for _ in 0..3 {
            t1.push(a.grow_reserved());
            t2.push(a.grow_reserved());
        }
        assert_eq!(a.free_pages(), 0);
        assert_eq!(a.reserved_pages(), 0);
        assert_eq!(a.outstanding(), 8);
        a.free(t1);
        a.free(t2);
        assert_eq!(a.free_pages(), 8);
    }

    #[test]
    fn conservation_holds_with_reservations_and_early_retirement() {
        let mut a = PageAllocator::new(11, 4);
        let t = a.admit(2, 6).unwrap();
        let mut t = t;
        t.push(a.grow_reserved()); // grew once, then hit a stop token
        a.unreserve(5); // the 5 unused reservations come back
        a.free(t);
        assert_eq!(a.free_pages(), 10);
        assert_eq!(a.reserved_pages(), 0);
        assert_eq!(a.outstanding(), 0);
    }

    #[test]
    #[should_panic(expected = "grow without a reservation")]
    fn growth_without_reservation_panics() {
        let mut a = PageAllocator::new(4, 4);
        a.grow_reserved();
    }

    // ---- refcounts (copy-on-write prefix sharing) ----

    #[test]
    fn shared_pages_free_only_on_last_release() {
        let mut a = PageAllocator::new(6, 4);
        let donor = a.alloc(2).unwrap();
        a.retain(donor[0]); // a second block table now references it
        assert_eq!(a.refcount(donor[0]), 2);
        assert_eq!(a.outstanding(), 2, "shared pages count once");
        a.free(donor.clone()); // donor retires first
        assert_eq!(a.free_pages() + a.outstanding(), a.usable_pages());
        assert_eq!(a.outstanding(), 1, "shared page survives the donor");
        a.release(donor[0]); // sharer retires
        assert_eq!(a.outstanding(), 0);
        assert_eq!(a.free_pages(), 5);
    }

    #[test]
    fn shared_page_is_not_reallocated_while_referenced() {
        let mut a = PageAllocator::new(4, 4);
        let t = a.alloc(3).unwrap();
        a.retain(t[1]);
        a.free(t.clone());
        // pages t[0], t[2] are free again; t[1] still referenced
        let again = a.alloc(2).unwrap();
        assert!(!again.contains(&t[1]), "referenced page must not be re-handed out");
        assert!(a.alloc(1).is_none());
        a.release(t[1]);
        assert!(a.alloc(1).is_some());
    }

    #[test]
    #[should_panic(expected = "retain of free page")]
    fn retain_of_free_page_panics() {
        let mut a = PageAllocator::new(4, 4);
        a.retain(2);
    }

    #[test]
    #[should_panic(expected = "reserved garbage page")]
    fn retain_of_reserved_page_panics() {
        let mut a = PageAllocator::new(4, 4);
        a.retain(RESERVED_PAGE);
    }

    // ---- parked pages (retained prefix pool) ----

    #[test]
    fn parked_pages_survive_release_and_free_on_evict() {
        let mut a = PageAllocator::new(6, 4); // 5 usable
        let t = a.alloc(3).unwrap();
        a.park(t[0]); // pool adopts the slot's reference to page t[0]
        a.park(t[1]);
        assert_eq!(a.retained_pages(), 2, "only the pool references them");
        assert_eq!(a.outstanding(), 1, "t[2] is plain slot state");
        a.audit();
        // a sharer re-activates a retained page: retained -> outstanding
        a.retain(t[0]);
        assert_eq!(a.retained_pages(), 1);
        assert_eq!(a.outstanding(), 2);
        // ... and its retirement parks it again (release, not free)
        a.release(t[0]);
        assert_eq!(a.retained_pages(), 2);
        assert_eq!(a.free_pages(), 2, "parked pages never hit the free list");
        a.audit();
        // eviction is the only door back to the free list
        a.evict(t[0]);
        a.evict(t[1]);
        a.release(t[2]);
        assert_eq!(a.free_pages(), 5);
        assert_eq!(a.retained_pages(), 0);
        assert_eq!(a.outstanding(), 0);
        a.audit();
    }

    #[test]
    #[should_panic(expected = "live block-table references")]
    fn evict_of_referenced_page_panics() {
        let mut a = PageAllocator::new(4, 4);
        let t = a.alloc(1).unwrap();
        a.park(t[0]);
        a.retain(t[0]); // a live block table references it
        a.evict(t[0]);
    }

    #[test]
    #[should_panic(expected = "prefix pool's own reference")]
    fn release_of_pool_reference_panics() {
        let mut a = PageAllocator::new(4, 4);
        let t = a.alloc(1).unwrap();
        a.park(t[0]); // refcount 1 now belongs to the pool
        a.release(t[0]);
    }

    #[test]
    #[should_panic(expected = "parked twice")]
    fn double_park_panics() {
        let mut a = PageAllocator::new(4, 4);
        let t = a.alloc(1).unwrap();
        a.park(t[0]);
        a.park(t[0]);
    }

    #[test]
    fn retained_pages_are_not_allocatable_but_are_conserved() {
        let mut a = PageAllocator::new(5, 4); // 4 usable
        let t = a.alloc(2).unwrap();
        a.park(t[0]);
        a.park(t[1]);
        // the 2 free pages allocate; the 2 retained ones do not
        assert!(a.alloc(3).is_none(), "retained pages must not allocate");
        let u = a.alloc(2).unwrap();
        assert!(!u.contains(&t[0]) && !u.contains(&t[1]));
        a.audit();
        a.free(u);
        a.evict(t[0]);
        a.evict(t[1]);
        assert_eq!(a.free_pages(), 4);
        a.audit();
    }

    // ---- overcommit watermark (two-tier hierarchy, PR 9) ----

    /// At factor 1.0 the overcommit gate is arithmetic-identical to the
    /// strict unreserved gate — the PR-8 baseline equivalence at the
    /// allocator level.
    #[test]
    fn overcommit_factor_one_is_the_strict_gate() {
        let mut a = PageAllocator::new(11, 4);
        a.set_overcommit(1.0);
        let t = a.admit(2, 5).unwrap();
        assert_eq!(a.admission_budget(), a.unreserved_pages());
        assert!(a.admit(4, 0).is_none(), "strict gate still refuses");
        assert!(a.admit(2, 1).is_some());
        // the strict ledger can never run dry: free >= reserved holds
        assert!(a.free_pages() >= a.reserved_pages());
        assert!(a.try_grow_reserved().is_some());
        a.audit();
        drop(t);
    }

    #[test]
    fn overcommit_admits_reservations_beyond_free() {
        let mut a = PageAllocator::new(9, 4); // 8 usable
        a.set_overcommit(1.5);
        // strict ledger: admit(1, 3) twice fills the pool (PR-3 test).
        // at 1.5x a third lazy slot is admitted on promised-only pages.
        let s1 = a.admit(1, 3).unwrap();
        let s2 = a.admit(1, 3).unwrap();
        assert_eq!(a.unreserved_pages(), 0, "strict headroom exhausted");
        assert_eq!(a.admission_budget(), 3, "floor(6 * 1.5) - 6");
        let s3 = a.admit(1, 2).unwrap();
        assert!(a.reserved_pages() > a.free_pages(), "ledger overcommitted");
        a.audit();
        // growth converts until the free list runs dry, then reports it
        let mut grown = Vec::new();
        while let Some(p) = a.try_grow_reserved() {
            grown.push(p);
        }
        assert!(a.free_pages() == 0 && a.reserved_pages() > 0, "growth ran dry");
        // preemption-shaped relief: the victim (s3) frees its page and
        // returns its untouched growth budget; growth resumes
        a.free(s3);
        a.unreserve(2);
        let p = a.try_grow_reserved().expect("freed pages un-dry growth");
        a.release(p);
        a.free(s1);
        a.free(s2);
        a.free(grown);
        a.unreserve(a.reserved_pages());
        assert_eq!(a.free_pages(), 8);
        a.audit();
    }

    #[test]
    fn overcommit_never_hands_out_missing_fresh_pages() {
        let mut a = PageAllocator::new(5, 4); // 4 usable
        a.set_overcommit(2.0);
        let t = a.alloc(3).unwrap();
        // budget inflates to 2 but only 1 physical page exists
        assert_eq!(a.admission_budget(), 2);
        assert!(a.admit(2, 0).is_none(), "fresh pages must physically exist");
        assert!(a.admit(1, 1).is_some(), "one fresh + one promised fits");
        a.free(t);
    }

    #[test]
    #[should_panic(expected = "finite value >= 1.0")]
    fn undercommit_factor_rejected() {
        let mut a = PageAllocator::new(4, 4);
        a.set_overcommit(0.5);
    }

    /// The satellite reclamation property at the allocator level: an
    /// induced mid-flight failure (abort) that releases tables and
    /// reservations restores full conservation, refcounted pages
    /// included.
    #[test]
    fn conservation_after_induced_abort_with_sharing_and_reservations() {
        let mut a = PageAllocator::new(21, 4); // 20 usable
        // slot A: eager-ish, 4 pages
        let ta = a.alloc(4).unwrap();
        // slot B: lazy, shares A's first 2 pages, 1 fresh + 3 reserved
        let mut tb = vec![ta[0], ta[1]];
        a.retain(ta[0]);
        a.retain(ta[1]);
        tb.extend(a.admit(1, 3).unwrap());
        tb.push(a.grow_reserved()); // B grew once before the failure
        assert_eq!(a.free_pages() + a.outstanding(), a.usable_pages());
        // induced failure: abort both mid-flight, in either order
        a.free(tb);
        a.unreserve(2); // B's remaining growth budget
        a.free(ta);
        assert_eq!(a.free_pages(), 20);
        assert_eq!(a.reserved_pages(), 0);
        assert_eq!(a.outstanding(), 0);
    }
}
