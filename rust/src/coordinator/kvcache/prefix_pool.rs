//! Retained prefix pool: a token-indexed LRU cache of parked
//! prompt-prefix pages (vLLM-style prefix caching with eviction).
//!
//! Copy-on-write prefix sharing (PR 4) only helps while a donor slot is
//! *in flight*: the moment the last block table referencing a prefix
//! page retires, the page frees and the next request with the same
//! system prompt re-stores it.  The pool closes that gap.  At slot
//! retirement the pages *fully covered* by the prompt (never pages a
//! decode row was written into) are not freed but **parked**: the pool
//! adopts the slot's reference ([`PageAllocator::park`]) and indexes
//! the pages under their exact token prefix.  Admission probes the
//! index exactly like it probes in-flight donors, so a hit re-shares
//! the parked pages copy-on-write through the PR-4 refcount machinery —
//! no new artifact, no device copy, zero prompt-page writes on a full
//! hit.
//!
//! **Eviction** is lazy and LRU: parked pages are reclaimed only when
//! an admission would otherwise starve ([`PrefixPool::evict_pages`]).
//! Entries are consumed oldest-stamp first and truncated **from the
//! tail**, because sharers always reference a *prefix* of an entry:
//! refcounts are non-increasing along an entry's pages, so the
//! evictable (refcount-1) pages form a suffix, and truncation keeps the
//! surviving entry a valid token prefix.  A page with a live
//! block-table reference is never evicted ([`PageAllocator::evict`]
//! enforces it).
//!
//! Entries own **disjoint** page sets (each parked page belongs to
//! exactly one entry — `park` enforces it), which keeps eviction
//! accounting exact.  Parking dedups against the index: a retiring
//! prefix already covered by an entry releases its (bit-identical)
//! duplicate pages instead of parking them, and a retiring extension of
//! an existing entry grows that entry in place.

use super::host_tier::HostTier;
use super::pagetable::PageAllocator;

/// One parked prompt prefix.  `tokens` always spans the entry's pages
/// exactly: `tokens.len() == pages.len() * page_size`.
#[derive(Clone, Debug)]
struct PrefixEntry {
    /// The token prefix whose KV the pages hold.
    tokens: Vec<i32>,
    /// Pool page ids, in position order (page `i` holds rows
    /// `i*page_size .. (i+1)*page_size`).
    pages: Vec<u32>,
    /// LRU clock value of the last hit/park touching this entry.
    stamp: u64,
}

/// Best index match for a prompt (see [`PrefixPool::lookup`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(super) struct PrefixHit {
    /// Index of the matched entry.
    pub idx: usize,
    /// Full pages of the entry covered by the common token prefix.
    pub pages: usize,
    /// Common token count (may extend into a partial page).
    pub common: usize,
}

/// The token-indexed LRU pool of parked prefix pages.
#[derive(Debug, Default)]
pub(super) struct PrefixPool {
    entries: Vec<PrefixEntry>,
    clock: u64,
}

impl PrefixPool {
    /// Number of live index entries (test observability only — the
    /// manager consumes the pool through `lookup`/`park`/`evict_pages`).
    #[cfg(test)]
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Best entry for `prompt`: the one sharing the most full pages of
    /// common token prefix (ties broken toward more common tokens).
    /// `None` when no entry shares at least one full page.
    pub fn lookup(&self, prompt: &[i32], page_size: usize) -> Option<PrefixHit> {
        let mut best: Option<PrefixHit> = None;
        for (idx, e) in self.entries.iter().enumerate() {
            let common = prompt
                .iter()
                .zip(e.tokens.iter())
                .take_while(|(a, b)| a == b)
                .count();
            let pages = (common / page_size).min(e.pages.len());
            if pages == 0 {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => pages > b.pages || (pages == b.pages && common > b.common),
            };
            if better {
                best = Some(PrefixHit { idx, pages, common });
            }
        }
        best
    }

    /// Bump an entry's LRU stamp (admission hit).
    pub fn touch(&mut self, idx: usize) {
        self.clock += 1;
        self.entries[idx].stamp = self.clock;
    }

    /// Page ids of one entry (admission shares a prefix of these).
    pub fn entry_pages(&self, idx: usize) -> &[u32] {
        &self.entries[idx].pages
    }

    /// Park a retiring slot's block table: the pages fully covered by
    /// `prompt` move into the index (the pool adopts this slot's
    /// references), everything else is released.  Dedups against the
    /// index: a prefix already covered releases its duplicate pages; a
    /// clean extension of an existing entry grows that entry in place;
    /// a divergent overlap is released without parking (entries must
    /// own disjoint pages).
    pub fn park(
        &mut self, prompt: &[i32], pages: Vec<u32>, page_size: usize,
        alloc: &mut PageAllocator,
    ) {
        let n_park = (prompt.len() / page_size).min(pages.len());
        if n_park == 0 {
            alloc.free(pages);
            return;
        }
        match self.lookup(prompt, page_size) {
            Some(hit) if hit.pages >= n_park => {
                // already covered (bit-identical KV): keep the existing
                // entry, release our duplicates / shared references
                self.touch(hit.idx);
                alloc.free(pages);
            }
            Some(hit) if self.entries[hit.idx].pages.len() == hit.pages => {
                // clean extension: the entry is a strict full-page
                // prefix of ours — grow it with our private tail pages
                // (ownership of those references transfers to the pool)
                let n = hit.pages;
                for &p in &pages[n..n_park] {
                    alloc.park(p);
                }
                self.clock += 1;
                let e = &mut self.entries[hit.idx];
                e.pages.extend_from_slice(&pages[n..n_park]);
                e.tokens = prompt[..n_park * page_size].to_vec();
                e.stamp = self.clock;
                // our references on the entry's own span and on any
                // decode-tail pages are ordinary releases
                for &p in pages[..n].iter().chain(&pages[n_park..]) {
                    alloc.release(p);
                }
            }
            Some(_) => {
                // divergent overlap (the entry's tokens turn away inside
                // its own span): parking would make two entries claim
                // the same leading pages, so skip — correctness first,
                // the hot-prompt case never lands here
                alloc.free(pages);
            }
            None => {
                for &p in &pages[..n_park] {
                    alloc.park(p);
                }
                self.clock += 1;
                self.entries.push(PrefixEntry {
                    tokens: prompt[..n_park * page_size].to_vec(),
                    pages: pages[..n_park].to_vec(),
                    stamp: self.clock,
                });
                for &p in &pages[n_park..] {
                    alloc.release(p);
                }
            }
        }
    }

    /// Evictable pages right now: per entry, the tail run of pages whose
    /// only reference is the pool's.  With `pin = Some((idx, n))` the
    /// first `n` pages of entry `idx` are treated as un-evictable (a
    /// planned admission is about to share them) — the read-only twin
    /// of the retain-pin [`Self::evict_pages`] callers apply.
    pub fn evictable_pages(
        &self, alloc: &PageAllocator, pin: Option<(usize, usize)>,
    ) -> usize {
        self.entries
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let tail = e
                    .pages
                    .iter()
                    .rev()
                    .take_while(|&&p| alloc.refcount(p) == 1)
                    .count();
                match pin {
                    Some((idx, n)) if idx == i => tail.min(e.pages.len() - n),
                    _ => tail,
                }
            })
            .sum()
    }

    /// Reclaim up to `want` parked pages, least-recently-used entries
    /// first, truncating each entry from the tail (only refcount-1
    /// pages — live references pin a page in place).  Emptied entries
    /// leave the index.  Returns the number of pages actually evicted.
    pub fn evict_pages(&mut self, want: usize, alloc: &mut PageAllocator) -> usize {
        let mut evicted = 0usize;
        while evicted < want {
            // oldest entry with an evictable tail page
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| {
                    e.pages.last().is_some_and(|&p| alloc.refcount(p) == 1)
                })
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i);
            let Some(i) = victim else { break };
            let e = &mut self.entries[i];
            while evicted < want {
                match e.pages.last() {
                    Some(&p) if alloc.refcount(p) == 1 => {
                        alloc.evict(p);
                        e.pages.pop();
                        evicted += 1;
                    }
                    _ => break,
                }
            }
            e.tokens.truncate(e.pages.len() * alloc.page_size());
            if e.pages.is_empty() {
                self.entries.swap_remove(i);
            }
        }
        evicted
    }

    /// Reclaim up to `want` parked pages like [`Self::evict_pages`],
    /// but **demote** instead of discard where possible: an LRU entry
    /// whose pages are all refcount-1 moves wholesale into the host
    /// tier (tokens + device page ids — the real engine captures the
    /// bytes through the tier's op log) before its device pages free,
    /// so the prefix survives admission pressure one level down the
    /// hierarchy.  Entries pinned by live sharers fall back to tail
    /// truncation — a tail alone is not a valid token prefix, so it
    /// cannot demote — and a tier refusal (capacity held by pins)
    /// degrades to plain eviction.  With the tier disabled this *is*
    /// [`Self::evict_pages`], bit for bit.  Returns the device pages
    /// reclaimed.
    pub fn spill_pages(
        &mut self, want: usize, alloc: &mut PageAllocator, tier: &mut HostTier,
    ) -> usize {
        if !tier.enabled() {
            return self.evict_pages(want, alloc);
        }
        let mut reclaimed = 0usize;
        while reclaimed < want {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| {
                    e.pages.last().is_some_and(|&p| alloc.refcount(p) == 1)
                })
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i);
            let Some(i) = victim else { break };
            let whole =
                self.entries[i].pages.iter().all(|&p| alloc.refcount(p) == 1);
            if whole {
                let e = self.entries.swap_remove(i);
                tier.store_prefix(&e.tokens, &e.pages);
                for &p in &e.pages {
                    alloc.evict(p);
                }
                reclaimed += e.pages.len();
            } else {
                let e = &mut self.entries[i];
                while reclaimed < want {
                    match e.pages.last() {
                        Some(&p) if alloc.refcount(p) == 1 => {
                            alloc.evict(p);
                            e.pages.pop();
                            reclaimed += 1;
                        }
                        _ => break,
                    }
                }
                e.tokens.truncate(e.pages.len() * alloc.page_size());
                if e.pages.is_empty() {
                    self.entries.swap_remove(i);
                }
            }
        }
        reclaimed
    }

    /// Drop every entry, releasing the pool's references (only used by
    /// tests/audits; serving keeps the pool alive for the next burst).
    #[cfg(test)]
    pub fn evict_all(&mut self, alloc: &mut PageAllocator) -> usize {
        self.evict_pages(usize::MAX, alloc)
    }

    /// Cross-check the index against the allocator: entries own
    /// disjoint, parked, referenced pages and span their tokens
    /// exactly.  Panics on the first violation (property tests call
    /// this after every step).
    pub fn audit(&self, alloc: &PageAllocator, page_size: usize) {
        let mut seen = std::collections::HashSet::new();
        for e in &self.entries {
            assert!(!e.pages.is_empty(), "empty entry left in the index");
            assert_eq!(
                e.tokens.len(),
                e.pages.len() * page_size,
                "entry tokens do not span its pages"
            );
            for &p in &e.pages {
                assert!(seen.insert(p), "page {p} owned by two entries");
                assert!(alloc.refcount(p) >= 1, "entry page {p} unreferenced");
            }
        }
        assert!(
            seen.len() >= alloc.retained_pages(),
            "allocator retains pages the index does not own"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PS: usize = 4;

    fn pool_with(alloc: &mut PageAllocator, tokens: &[i32]) -> (PrefixPool, Vec<u32>) {
        // simulate a retiring slot: prompt `tokens`, table covering the
        // prompt pages plus one decode page
        let n = tokens.len().div_ceil(PS) + 1;
        let pages = alloc.alloc(n).unwrap();
        let mut pool = PrefixPool::default();
        pool.park(tokens, pages.clone(), PS, alloc);
        (pool, pages)
    }

    #[test]
    fn park_keeps_full_prompt_pages_and_releases_the_tail() {
        let mut a = PageAllocator::new(12, PS);
        // 10-token prompt: 2 full pages parked, partial page 3 + decode
        // page released
        let toks: Vec<i32> = (0..10).collect();
        let (pool, pages) = pool_with(&mut a, &toks);
        assert_eq!(pool.entries(), 1);
        assert_eq!(a.retained_pages(), 2);
        assert_eq!(a.outstanding(), 0);
        assert_eq!(a.free_pages(), 9);
        pool.audit(&a, PS);
        a.audit();
        // lookup finds the full-page overlap only (common is capped by
        // the entry's own 8 parked tokens)
        let hit = pool.lookup(&toks, PS).unwrap();
        assert_eq!((hit.pages, hit.common), (2, 8));
        assert_eq!(pool.entry_pages(hit.idx), &pages[..2]);
        // an unrelated prompt misses
        assert!(pool.lookup(&[99; 10], PS).is_none());
    }

    #[test]
    fn duplicate_park_releases_instead_of_double_indexing() {
        let mut a = PageAllocator::new(12, PS);
        let toks: Vec<i32> = (0..8).collect();
        let (mut pool, _) = pool_with(&mut a, &toks);
        // a second slot with the same prompt retires: its private pages
        // are duplicates of the entry's and must free, not park
        let dup = a.alloc(3).unwrap();
        pool.park(&toks, dup, PS, &mut a);
        assert_eq!(pool.entries(), 1, "no duplicate entry");
        assert_eq!(a.retained_pages(), 2);
        a.audit();
        pool.audit(&a, PS);
    }

    #[test]
    fn extension_grows_the_entry_in_place() {
        let mut a = PageAllocator::new(12, PS);
        let short: Vec<i32> = (0..4).collect(); // exactly one page
        let (mut pool, first) = pool_with(&mut a, &short);
        assert_eq!(a.retained_pages(), 1);
        // a longer prompt with the same first page retires; its table
        // shared the entry's page 0 (refcounted) and adds private tail
        let long: Vec<i32> = (0..12).collect(); // three full pages
        a.retain(first[0]);
        let mut table = vec![first[0]];
        table.extend(a.alloc(3).unwrap()); // 2 prompt pages + decode page
        pool.park(&long, table, PS, &mut a);
        assert_eq!(pool.entries(), 1, "extension, not a second entry");
        let hit = pool.lookup(&long, PS).unwrap();
        assert_eq!(hit.pages, 3, "entry now covers all three pages");
        assert_eq!(pool.entry_pages(hit.idx)[0], first[0], "page 0 kept");
        assert_eq!(a.retained_pages(), 3);
        assert_eq!(a.outstanding(), 0);
        a.audit();
        pool.audit(&a, PS);
    }

    #[test]
    fn lru_eviction_truncates_tails_and_skips_live_references() {
        let mut a = PageAllocator::new(16, PS);
        let old: Vec<i32> = (100..108).collect(); // 2 pages, parked first
        let (mut pool, old_pages) = pool_with(&mut a, &old);
        let hot: Vec<i32> = (200..208).collect(); // 2 pages, newer
        let hot_pages = {
            let n = hot.len().div_ceil(PS) + 1;
            let pages = a.alloc(n).unwrap();
            pool.park(&hot, pages.clone(), PS, &mut a);
            pages
        };
        assert_eq!(a.retained_pages(), 4);
        // a live sharer pins the old entry's first page
        a.retain(old_pages[0]);
        assert_eq!(pool.evictable_pages(&a, None), 3);
        // want 2: the old entry's tail page goes first (LRU), then the
        // newer entry's tail — the pinned page is never touched
        let got = pool.evict_pages(2, &mut a);
        assert_eq!(got, 2);
        assert_eq!(a.refcount(old_pages[0]), 2, "pinned page survives");
        assert_eq!(a.refcount(old_pages[1]), 0, "old tail evicted");
        assert_eq!(a.refcount(hot_pages[1]), 0, "hot tail evicted next");
        assert_eq!(a.refcount(hot_pages[0]), 1, "hot head still parked");
        a.audit();
        pool.audit(&a, PS);
        // the truncated entries still serve their shorter prefixes
        assert_eq!(pool.lookup(&hot, PS).unwrap().pages, 1);
        // draining everything empties the index (pinned page stays)
        let rest = pool.evict_all(&mut a);
        assert_eq!(rest, 1);
        assert_eq!(pool.entries(), 1, "pinned entry survives, truncated");
        assert_eq!(pool.evictable_pages(&a, None), 0);
        a.release(old_pages[0]); // sharer retires -> retained again
        assert_eq!(pool.evict_all(&mut a), 1);
        assert_eq!(pool.entries(), 0);
        assert_eq!(a.retained_pages(), 0);
        a.audit();
    }

    #[test]
    fn spill_demotes_whole_entries_and_truncates_pinned_ones() {
        use super::super::host_tier::{HostTier, HostTierConfig};
        let mut a = PageAllocator::new(16, PS);
        let cold: Vec<i32> = (100..108).collect(); // 2 pages, LRU-oldest
        let (mut pool, cold_pages) = pool_with(&mut a, &cold);
        let hot: Vec<i32> = (200..212).collect(); // 3 pages, newer
        {
            let n = hot.len().div_ceil(PS) + 1;
            let pages = a.alloc(n).unwrap();
            pool.park(&hot, pages, PS, &mut a);
        }
        assert_eq!(a.retained_pages(), 5);
        // a live sharer pins the hot entry's head
        let hot_head = pool.entry_pages(pool.lookup(&hot, PS).unwrap().idx)[0];
        a.retain(hot_head);
        let mut tier =
            HostTier::new(HostTierConfig { capacity_bytes: 1024, page_bytes: 64 });
        // want 4: the cold entry (all refcount-1) demotes wholesale,
        // the pinned hot entry only truncates its refcount-1 tail
        let got = pool.spill_pages(4, &mut a, &mut tier);
        assert_eq!(got, 4);
        assert_eq!(tier.stats().demoted_pages, 2, "only the whole entry demoted");
        assert_eq!(tier.peek_prefix(&cold), Some(2), "cold prefix survives on host");
        assert!(tier.peek_prefix(&hot).is_none(), "truncated tail cannot demote");
        assert_eq!(a.refcount(cold_pages[0]), 0, "demoted device pages freed");
        assert_eq!(pool.lookup(&hot, PS).unwrap().pages, 1, "hot head survives");
        a.release(hot_head);
        a.audit();
        pool.audit(&a, PS);
        // disabled tier degrades to plain eviction
        let mut off = HostTier::new(HostTierConfig::default());
        let got = pool.spill_pages(1, &mut a, &mut off);
        assert_eq!(got, 1);
        assert_eq!(off.stats().demoted_pages, 0);
        assert_eq!(a.retained_pages(), 0);
        a.audit();
    }

    #[test]
    fn pin_excludes_planned_shares_from_the_evictable_count() {
        let mut a = PageAllocator::new(12, PS);
        let toks: Vec<i32> = (0..12).collect(); // 3 full pages
        let (pool, _) = pool_with(&mut a, &toks);
        assert_eq!(pool.evictable_pages(&a, None), 3);
        let hit = pool.lookup(&toks, PS).unwrap();
        // an admission about to share 2 pages may only count the third
        assert_eq!(pool.evictable_pages(&a, Some((hit.idx, 2))), 1);
        assert_eq!(pool.evictable_pages(&a, Some((hit.idx, 3))), 0);
    }
}
