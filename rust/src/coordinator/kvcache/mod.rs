//! The KV-cache manager: every paged-cache *policy* decision behind one
//! narrow API, so the engine stays pure batch orchestration.
//!
//! PRs 3–4 grew the paged KV cache (block tables, lazy growth,
//! copy-on-write prefix sharing) inside `coordinator/engine.rs`, tangled
//! with artifact scheduling.  This module is that policy carved out:
//!
//! * [`pagetable`] — the refcounted free-list [`PageAllocator`] with the
//!   reservation ledger (lazy growth) and the parked-page state
//!   (retained prefixes);
//! * [`prefix_pool`](self) *(private)* — the token-indexed LRU pool of
//!   retained prompt prefixes;
//! * [`KvCacheManager`] — the façade the engine drives:
//!   - [`admit`](KvCacheManager::admit) / [`install`](KvCacheManager::install)
//!     — plan and commit one admission (fresh pages + growth
//!     reservation, net of prefix pages shared from in-flight donors
//!     *or* the retained pool), then bind it to a batch slot;
//!   - [`grow_to`](KvCacheManager::grow_to) — convert reservations into
//!     real pages as a slot's position crosses page boundaries;
//!   - [`release`](KvCacheManager::release) — retire or abort a slot:
//!     reservations return to the pool, and on clean retirement the
//!     pages fully covered by the prompt are **parked** in the retained
//!     prefix pool instead of freed.
//!
//! **Retention lifecycle.**  A hot system prompt's KV pages survive idle
//! gaps: retirement parks them (pool adopts the slot's reference),
//! admission probes the pool exactly like it probes in-flight donors
//! and re-shares hits copy-on-write through the PR-4 refcount
//! machinery, and a lazy LRU evictor reclaims parked pages only when an
//! admission would otherwise starve.  The allocator-level partition
//! `free + outstanding + retained == usable` and the no-deadlock
//! guarantee `free >= reserved` hold at every step
//! (`prop_prefix_pool_conservation`), and a page with a live
//! block-table reference is never evicted.
//!
//! The manager is pure bookkeeping — no device buffers, no runtime
//! calls — so the whole policy is unit- and property-testable without
//! artifacts, and the Python protocol twin
//! (`python/tests/test_paged_serving_protocol.py`) mirrors it
//! operation for operation.

pub mod pagetable;
mod prefix_pool;

use std::collections::VecDeque;

use anyhow::Result;

use crate::tensor::Tensor;
use pagetable::{PageAllocator, RESERVED_PAGE};
use prefix_pool::PrefixPool;

/// Which on-device layout carries the live KV state (see the engine's
/// module docs for the buffer shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// Dense per-slot caches `(L, B, Tmax, nh, dh)`, padded to the
    /// worst-case `max_len` — the compatibility/equivalence baseline.
    Dense,
    /// Shared page pools `(L, num_pages, page_size, nh, dh)` addressed
    /// through per-slot block tables; memory tracks actual contexts.
    Paged,
}

/// Cache-policy knobs (the engine copies these out of `EngineConfig`).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Lazy page growth: admit with prompt pages + one decode page and
    /// grow from the reservation ledger as `pos` advances.  `false`
    /// restores eager worst-case-at-admission allocation (PR 3).
    pub lazy_growth: bool,
    /// Copy-on-write prompt-prefix sharing across in-flight block
    /// tables (PR 4).
    pub share_prefixes: bool,
    /// Retained prefix pool: park prompt-prefix pages at retirement and
    /// serve later admissions from them (LRU-evicted under pressure).
    /// Requires `share_prefixes`; `false` restores the PR-4 baseline
    /// where prefix pages die with their last block-table reference.
    pub prefix_cache: bool,
    /// Chunked-prefill admission (`Some(chunk_rows)`): a lazy admission
    /// grants only the pages covering the prompt's *first chunk*
    /// (`min(prompt_len, chunk_rows)` rows, never fewer than the shared
    /// prefix pages) and reserves the rest of the worst case; chunk
    /// advances convert reservations through
    /// [`KvCacheManager::grow_prefill`].  `None` (default) keeps the
    /// monolithic prompt-pages-plus-decode-page grant.  Prefix-pool
    /// probing is unchanged, but live CoW donors are restricted to
    /// slots whose prefill has *completed*
    /// ([`KvCacheManager::mark_prefilled`]): a mid-chunk slot's pages
    /// hold no KV yet, and chunking breaks the monolithic guarantee
    /// that a whole admission wave prefills (or requeues) atomically —
    /// a sharer could outrun or outlive an unwritten donor and read
    /// garbage or permanently orphan the shared page.
    pub chunk_rows: Option<usize>,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            lazy_growth: true,
            share_prefixes: true,
            prefix_cache: true,
            chunk_rows: None,
        }
    }
}

/// Monotonic counters of the manager's policy machinery (mirrored into
/// `EngineMetrics` by the engine after every tick).
#[derive(Clone, Debug, Default)]
pub struct KvMetrics {
    /// Pages allocated lazily mid-flight, one per page-boundary
    /// crossing, out of the slot's admission-time reservation.
    pub page_grows: u64,
    /// Block-table entries admitted as references to a donor's (or the
    /// retained pool's) prompt-prefix pages instead of fresh
    /// allocations.
    pub shared_pages: u64,
    /// Copy-on-write events: admissions whose common prefix ran into a
    /// page the appended decode row could write, so that page was made
    /// private and the slot's own `page_append` performed the copy.
    pub cow_copies: u64,
    /// Admissions that re-shared at least one page from the retained
    /// prefix pool.
    pub prefix_hits: u64,
    /// Prompt tokens whose KV was served from the retained pool instead
    /// of being recomputed and re-stored (full pages only).
    pub prefix_hit_tokens: u64,
    /// Retained pages reclaimed by the LRU evictor because an admission
    /// would otherwise have starved.
    pub evictions: u64,
}

/// One planned admission: how much of the worst-case page need
/// (`ceil(min(prompt + max_new, max_len) / page_size)`) is shared from
/// a donor or the retained pool, allocated now, or reserved for lazy
/// growth.
#[derive(Clone, Debug, PartialEq, Eq)]
struct AdmitPlan {
    /// Donor prefix pages the new block table will reference
    /// (refcounted; always fully covered by the common token prefix of
    /// both prompts, so neither side ever writes them).
    shared: Vec<u32>,
    /// Pages to allocate fresh at admission.
    fresh: usize,
    /// Worst-case growth budget to reserve (0 under eager admission).
    reserve: usize,
    /// The common prefix extended into a page the appended decode row
    /// could write: that page was made private instead of shared, and
    /// the slot's own `page_append` write performs the copy (the
    /// copy-on-write event).
    cow_copy: bool,
    /// `Some((entry, pages))` when the winning donor was a retained
    /// prefix-pool entry rather than an in-flight slot.
    pool_hit: Option<(usize, usize)>,
}

/// An admission committed in the allocator but not yet bound to a batch
/// slot (the refill loop learns slot indices only after its gate ran).
#[derive(Clone, Debug)]
struct Admission {
    table: Vec<u32>,
    shared: usize,
    reserve: usize,
    prompt: Vec<i32>,
}

/// Paged-layout policy state (block tables + page ownership + the
/// retained prefix pool).
#[derive(Debug)]
struct PagedBook {
    /// Free-list over the pool's page ids (page 0 reserved).
    allocator: PageAllocator,
    /// Retained prefix index (LRU-evicted parked pages).
    pool: PrefixPool,
    /// Block-table width (pages addressable per slot).
    pages_per_slot: usize,
    /// Per-slot page ids, in position order; empty for free slots.  The
    /// leading `shared[slot]` entries are references to a donor's or
    /// the pool's prefix pages (refcounted, never written by this
    /// slot).
    tables: Vec<Vec<u32>>,
    /// Per-slot admitted prompt (sharing-donor lookup + parking key).
    prompts: Vec<Vec<i32>>,
    /// Per-slot remaining growth budget, mirrored in the allocator's
    /// reservation ledger.
    reserved: Vec<usize>,
    /// Per-slot count of leading block-table entries shared from a
    /// donor (`page_append` routes these chunks to the garbage page).
    shared: Vec<usize>,
    /// Per-slot "prompt KV fully written" flag
    /// ([`KvCacheManager::mark_prefilled`]).  Only consulted under
    /// chunked admission, where it gates CoW donor eligibility; the
    /// monolithic paths keep their PR-6 behaviour bit-for-bit.
    prefilled: Vec<bool>,
    /// Admissions committed by [`KvCacheManager::admit`] awaiting their
    /// [`KvCacheManager::install`] slot binding, in FIFO order.
    pending: VecDeque<Admission>,
}

/// The KV-cache policy façade (see the module docs).
#[derive(Debug)]
pub struct KvCacheManager {
    /// `None` on the dense layout — every method degrades to a no-op /
    /// always-admit there, so the engine drives one code path.
    book: Option<PagedBook>,
    cfg: KvCacheConfig,
    width: usize,
    max_len: usize,
    metrics: KvMetrics,
}

impl KvCacheManager {
    /// Manager for the dense layout: no page accounting, every request
    /// admissible, every policy call a no-op.
    pub fn dense(width: usize, max_len: usize, cfg: KvCacheConfig) -> Self {
        KvCacheManager { book: None, cfg, width, max_len, metrics: KvMetrics::default() }
    }

    /// Manager for the paged layout with the given pool geometry
    /// (validated upstream against the artifact manifest).
    pub fn paged(
        width: usize, max_len: usize, num_pages: usize, page_size: usize,
        pages_per_slot: usize, mut cfg: KvCacheConfig,
    ) -> Self {
        if cfg.prefix_cache && !cfg.share_prefixes {
            // retention rides on the CoW sharing machinery: with
            // sharing off there is no path that could re-share a
            // parked page, so normalize instead of silently no-opping
            log::info!(
                "kvcache: prefix_cache requires share_prefixes — \
                 retention disabled (PR-4 baseline semantics)"
            );
            cfg.prefix_cache = false;
        }
        KvCacheManager {
            book: Some(PagedBook {
                allocator: PageAllocator::new(num_pages, page_size),
                pool: PrefixPool::default(),
                pages_per_slot,
                tables: vec![Vec::new(); width],
                prompts: vec![Vec::new(); width],
                reserved: vec![0; width],
                shared: vec![0; width],
                prefilled: vec![false; width],
                pending: VecDeque::new(),
            }),
            cfg,
            width,
            max_len,
            metrics: KvMetrics::default(),
        }
    }

    /// Which layout this manager books for.
    pub fn layout(&self) -> KvLayout {
        if self.book.is_some() { KvLayout::Paged } else { KvLayout::Dense }
    }

    /// Policy counters (monotonic; the engine mirrors them into
    /// `EngineMetrics`).
    pub fn metrics(&self) -> &KvMetrics {
        &self.metrics
    }

    /// Reclaimable / total usable pool pages (`None` on the dense
    /// layout).  "Reclaimable" counts the free list — growth headroom
    /// reserved by in-flight slots included — plus the retained prefix
    /// pool, which the LRU evictor returns on demand; after a full
    /// drain it equals the usable pool (the conservation check the
    /// reclamation tests pin).
    pub fn page_budget(&self) -> Option<(usize, usize)> {
        self.book.as_ref().map(|b| {
            (
                b.allocator.free_pages() + b.allocator.retained_pages(),
                b.allocator.usable_pages(),
            )
        })
    }

    /// Free pages promised to in-flight slots for lazy growth (`None`
    /// on the dense layout; 0 after a full drain).
    pub fn reservations(&self) -> Option<usize> {
        self.book.as_ref().map(|b| b.allocator.reserved_pages())
    }

    /// Pages currently parked in the retained prefix pool (`None` on
    /// the dense layout).
    pub fn retained_pages(&self) -> Option<usize> {
        self.book.as_ref().map(|b| b.allocator.retained_pages())
    }

    /// Rows per pool page (`None` on the dense layout).
    pub fn page_size(&self) -> Option<usize> {
        self.book.as_ref().map(|b| b.allocator.page_size())
    }

    /// Warm-start the retained prefix pool with `prompt`'s full-page
    /// prefix, as if a slot with that prompt had just retired (the host
    /// prefix store's download path, see `coordinator::cluster`).
    /// Pages come from the *unreserved* free pool only — a preload
    /// never competes with committed growth reservations and never
    /// evicts locally-warmed entries — and they enter the pool through
    /// the same [`PrefixPool::park`] path retirement uses, so dedup
    /// against existing entries, LRU eviction, and the allocator's
    /// conservation ledger all hold unchanged.  Returns the pages
    /// actually added to the retained pool: 0 on the dense layout, with
    /// retention off, when the pool already covers the prefix, or when
    /// free headroom is insufficient.
    pub fn preload_prefix(&mut self, prompt: &[i32]) -> usize {
        if !self.cfg.prefix_cache {
            return 0;
        }
        let Some(book) = &mut self.book else { return 0 };
        let page_size = book.allocator.page_size();
        let full_pages = prompt.len() / page_size;
        if full_pages == 0 {
            return 0;
        }
        if book
            .pool
            .lookup(prompt, page_size)
            .is_some_and(|h| h.pages >= full_pages)
        {
            return 0;
        }
        let Some(pages) = book.allocator.alloc(full_pages) else {
            return 0;
        };
        let before = book.allocator.retained_pages();
        // park() dedups against overlapping entries and frees whatever
        // it does not keep — the retained delta is what the download
        // actually installed
        book.pool.park(
            &prompt[..full_pages * page_size],
            pages,
            page_size,
            &mut book.allocator,
        );
        book.allocator.retained_pages() - before
    }

    /// Worst-case pages a request needs over its whole lifetime
    /// (prompt + generation budget, clamped to the context span) — what
    /// eager admission allocates and lazy admission commits (allocated
    /// + reserved).  0 on the dense layout.
    pub fn pages_needed(&self, prompt_len: usize, max_new: usize) -> usize {
        match &self.book {
            None => 0,
            Some(b) => {
                let rows = (prompt_len.max(1) + max_new).min(self.max_len);
                b.allocator.pages_for(rows)
            }
        }
    }

    /// Whether a request of this shape could EVER be admitted: its
    /// worst-case commitment must fit the whole usable pool (neither
    /// prefix sharing nor retention is assumed — donors are transient
    /// and retained pages evict).  `false` means reject at submit, or
    /// the request would head-block the FIFO queue forever.
    pub fn ever_admissible(&self, prompt_len: usize, max_new: usize) -> bool {
        match &self.book {
            None => true,
            Some(b) => {
                self.pages_needed(prompt_len, max_new) <= b.allocator.usable_pages()
            }
        }
    }

    /// Plan one admission against the current donors: in-flight slots,
    /// admissions pending installation, the caller's extra simulated
    /// donors, and — strictly last, so live donors win ties — the
    /// retained prefix pool.  Sharing is restricted to pages *fully
    /// covered* by the common token prefix: any page a decode row could
    /// land in (positions `>= prompt_len` for either side) must be
    /// private, because pool pages are only ever written through a
    /// slot's own block-table entry.  The boundary page the common
    /// prefix runs into is therefore copied — by the admission's own
    /// `page_append` write, not a device copy — exactly when it would
    /// otherwise be written (`cow_copy`).
    fn plan(
        &self, prompt: &[i32], max_new: usize, extra: &[(Vec<i32>, Vec<u32>)],
    ) -> AdmitPlan {
        let book = self.book.as_ref().expect("plan on the dense layout");
        let page_size = book.allocator.page_size();
        let plen = prompt.len().max(1);
        let worst = (plen + max_new).min(self.max_len).div_ceil(page_size);
        let prompt_pages = plen.div_ceil(page_size);
        let mut shared: Vec<u32> = Vec::new();
        let mut best_common = 0usize;
        let mut pool_hit = None;
        if self.cfg.share_prefixes {
            // Chunked admission shares only from prefill-COMPLETE live
            // donors, and never from same-wave pending admissions: an
            // unwritten donor's pages hold no KV, and without the
            // monolithic wave's atomic prefill-or-requeue a sharer can
            // outrun or outlive the donor (see `chunk_rows` docs).
            let chunked = self.cfg.chunk_rows.is_some();
            let live = book
                .tables
                .iter()
                .zip(&book.prompts)
                .zip(&book.prefilled)
                .filter(move |((t, _), &done)| !t.is_empty() && (!chunked || done))
                .map(|((t, p), _)| (p.as_slice(), t.as_slice()));
            let pend = book
                .pending
                .iter()
                .filter(move |_| !chunked)
                .map(|a| (a.prompt.as_slice(), a.table.as_slice()));
            let sim = extra.iter().map(|(p, t)| (p.as_slice(), t.as_slice()));
            // NOTE: this scoring (common tokens → full shared pages →
            // best by (pages, common)) must stay in lockstep with
            // `PrefixPool::lookup` — the pool is probed "exactly like a
            // donor", and a divergence would rank the two differently
            for (donor_prompt, donor_table) in live.chain(pend).chain(sim) {
                let common = prompt
                    .iter()
                    .zip(donor_prompt.iter())
                    .take_while(|(a, b)| a == b)
                    .count();
                // full pages inside BOTH prompts (common <= both
                // lengths); a donor's table always covers its own
                // prompt pages
                let n = (common / page_size).min(donor_table.len());
                if n > shared.len() || (n == shared.len() && common > best_common) {
                    shared = donor_table[..n].to_vec();
                    best_common = common;
                }
            }
            if self.cfg.prefix_cache {
                if let Some(hit) = book.pool.lookup(prompt, page_size) {
                    if hit.pages > shared.len()
                        || (hit.pages == shared.len() && hit.common > best_common)
                    {
                        shared = book.pool.entry_pages(hit.idx)[..hit.pages].to_vec();
                        best_common = hit.common;
                        pool_hit = Some((hit.idx, hit.pages));
                    }
                }
            }
        }
        let n_share = shared.len();
        debug_assert!(n_share <= prompt_pages);
        // lazy: prompt pages + one decode page (capped at the worst
        // case); eager: the full worst case, nothing reserved; chunked
        // lazy: only the first chunk's pages (never fewer than the
        // shared prefix — those entries live in the table from day one)
        let table_len = match (self.cfg.lazy_growth, self.cfg.chunk_rows) {
            (false, _) => worst,
            (true, None) => (prompt_pages + 1).min(worst),
            (true, Some(chunk)) => {
                let chunk_pages = plen.min(chunk.max(1)).div_ceil(page_size);
                chunk_pages.max(n_share).min(worst)
            }
        };
        AdmitPlan {
            fresh: table_len - n_share,
            reserve: worst - table_len,
            // only a real sharing admission can copy-on-write: the
            // boundary page is "copied" when the common prefix extends
            // past the last fully-shared page (sub-page overlaps with
            // no shared pages are ordinary private admissions)
            cow_copy: n_share > 0 && best_common > n_share * page_size,
            shared,
            pool_hit,
        }
    }

    /// Requests the scheduler may admit *this* tick: the FIFO prefix of
    /// `queued` (pairs of prompt + decode budget, `total` long) whose
    /// page commitments — fresh + reserved, net of shareable prefix
    /// pages — fit the *unreserved* pool.  The **head** additionally
    /// counts the LRU-evictable retained pages its admission could
    /// reclaim, in exactly the arithmetic [`Self::admit`] commits —
    /// this head-exactness is load-bearing: if the simulation said 0
    /// where the real gate would admit, a queue whose pages are all
    /// parked would read as page-starved forever and the engine's
    /// liveness guard would fire.  Later candidates use the plain
    /// unreserved budget (conservative: the head's admission already
    /// guarantees the tick makes progress).
    pub fn admissible_now<'a, I>(&self, queued: I, total: usize, empty: usize) -> usize
    where
        I: Iterator<Item = (&'a [i32], usize)>,
    {
        let Some(book) = &self.book else { return total };
        let limit = total.min(empty);
        if limit == 0 {
            return 0; // steady-state decode tick: skip the donor scan
        }
        let mut budget = book.allocator.unreserved_pages();
        let mut extra: Vec<(Vec<i32>, Vec<u32>)> = Vec::new();
        let mut admissible = 0usize;
        for (prompt, max_new) in queued.take(limit) {
            let plan = self.plan(prompt, max_new, &extra);
            let need = plan.fresh + plan.reserve;
            let fits = need <= budget
                || (admissible == 0
                    && need - budget
                        <= book.pool.evictable_pages(&book.allocator, plan.pool_hit));
            if !fits {
                break;
            }
            budget = budget.saturating_sub(need);
            admissible += 1;
            if self.cfg.share_prefixes && self.cfg.chunk_rows.is_none() {
                // page ids are placeholders — only the table LENGTH
                // matters for later candidates' share planning.  Skipped
                // under chunked admission, where same-wave donors are
                // ineligible (their pages are unwritten) — the sim must
                // mirror the gate's arithmetic exactly
                let len = plan.shared.len() + plan.fresh;
                extra.push((prompt.to_vec(), vec![RESERVED_PAGE; len]));
            }
        }
        admissible
    }

    /// Plan and **commit** one admission: allocate its fresh pages,
    /// reserve its growth budget, take references on its shared prefix
    /// pages, and queue the built block table for [`Self::install`].
    /// When the unreserved pool cannot cover the need, the LRU evictor
    /// reclaims retained pages first (pinning the planned shares so
    /// they survive) — but only when eviction actually covers the
    /// deficit: a starved admission must not trash retained prefixes
    /// it cannot be unblocked by.  `false` means genuine starvation —
    /// the caller stops its refill so FIFO order holds.  Always `true`
    /// on the dense layout.
    pub fn admit(&mut self, prompt: &[i32], max_new: usize) -> bool {
        if self.book.is_none() {
            return true;
        }
        let plan = self.plan(prompt, max_new, &[]);
        let book = self.book.as_mut().expect("checked above");
        let need = plan.fresh + plan.reserve;
        if need > book.allocator.unreserved_pages() {
            // pin the planned shares: LRU eviction must not reclaim the
            // very pages this admission is about to reference (and with
            // the pins baked into the refcounts, the evictable count is
            // exactly what evict_pages could reclaim)
            for &p in &plan.shared {
                book.allocator.retain(p);
            }
            let deficit = need - book.allocator.unreserved_pages();
            if deficit <= book.pool.evictable_pages(&book.allocator, None) {
                let evicted = book.pool.evict_pages(deficit, &mut book.allocator);
                self.metrics.evictions += evicted as u64;
            }
            // else: genuine starvation — evicting the reclaimable few
            // would trash retained prefixes without unblocking anything
            for &p in &plan.shared {
                book.allocator.release(p);
            }
            if need > book.allocator.unreserved_pages() {
                return false;
            }
        }
        let fresh = book
            .allocator
            .admit(plan.fresh, plan.reserve)
            .expect("admission was gated on unreserved pages");
        for &p in &plan.shared {
            book.allocator.retain(p);
        }
        self.metrics.shared_pages += plan.shared.len() as u64;
        self.metrics.cow_copies += plan.cow_copy as u64;
        if let Some((_, pages)) = plan.pool_hit {
            self.metrics.prefix_hits += 1;
            self.metrics.prefix_hit_tokens +=
                (pages * book.allocator.page_size()) as u64;
            // re-look the entry up rather than trusting the planned
            // index: eviction above may have compacted the index
            if let Some(hit) = book.pool.lookup(prompt, book.allocator.page_size()) {
                book.pool.touch(hit.idx);
            }
        }
        let shared_n = plan.shared.len();
        let mut table = plan.shared;
        table.extend(fresh);
        book.pending.push_back(Admission {
            table,
            shared: shared_n,
            reserve: plan.reserve,
            prompt: prompt.to_vec(),
        });
        true
    }

    /// Bind the oldest committed-but-unbound admission to batch slot
    /// `slot` (the refill loop learns indices only after its admission
    /// gate ran; FIFO order matches by construction).  No-op on the
    /// dense layout.
    pub fn install(&mut self, slot: usize) {
        let Some(book) = &mut self.book else { return };
        let adm = book.pending.pop_front().expect("install without a pending admit");
        book.tables[slot] = adm.table;
        book.shared[slot] = adm.shared;
        book.reserved[slot] = adm.reserve;
        book.prompts[slot] = adm.prompt;
        book.prefilled[slot] = false;
    }

    /// Record that `slot`'s prompt KV is fully written (the engine calls
    /// this when the slot's prefill commits).  Under chunked admission
    /// this is what makes the slot eligible as a CoW prefix donor; the
    /// monolithic planner ignores the flag.  No-op on the dense layout.
    pub fn mark_prefilled(&mut self, slot: usize) {
        if let Some(book) = &mut self.book {
            book.prefilled[slot] = true;
        }
    }

    /// Admissions committed but not yet bound to a slot (0 between
    /// refill waves — asserted by the engine and the property tests).
    pub fn pending_installs(&self) -> usize {
        self.book.as_ref().map_or(0, |b| b.pending.len())
    }

    /// Lazy growth: extend `slot`'s block table until it covers a KV
    /// write at `pos`, converting one admission-time reservation per
    /// page.  The ledger guarantees the conversion succeeds — a failure
    /// here is a page-accounting bug, not backpressure.  No-op on the
    /// dense layout.
    pub fn grow_to(&mut self, slot: usize, pos: usize) -> Result<()> {
        let Some(book) = &mut self.book else { return Ok(()) };
        let page_size = book.allocator.page_size();
        let needed = pos / page_size + 1;
        while book.tables[slot].len() < needed {
            anyhow::ensure!(
                book.reserved[slot] > 0,
                "slot {slot} needs page {} of {needed} with no reservation left \
                 (pos {pos}) — lazy-growth accounting bug",
                book.tables[slot].len(),
            );
            let page = book.allocator.grow_reserved();
            book.reserved[slot] -= 1;
            book.tables[slot].push(page);
            self.metrics.page_grows += 1;
        }
        // CoW invariant: the page receiving this tick's appended row is
        // past the shared prefix and private to this slot
        debug_assert!(
            needed - 1 >= book.shared[slot],
            "decode write would land in a shared prefix page"
        );
        debug_assert_eq!(book.allocator.refcount(book.tables[slot][needed - 1]), 1);
        Ok(())
    }

    /// Chunked-prefill growth: extend `slot`'s block table until it
    /// covers the first `rows` prompt rows, converting reservations like
    /// [`Self::grow_to`].  Unlike `grow_to` this carries no CoW write
    /// asserts — a chunk walk legitimately passes *through* the shared
    /// prefix (those pages are already in the table and the append-side
    /// block table routes their rows to the garbage page, so they are
    /// never written).  No-op on the dense layout or when the table
    /// already covers the rows.
    pub fn grow_prefill(&mut self, slot: usize, rows: usize) -> Result<()> {
        let Some(book) = &mut self.book else { return Ok(()) };
        let page_size = book.allocator.page_size();
        let needed = rows.max(1).div_ceil(page_size);
        while book.tables[slot].len() < needed {
            anyhow::ensure!(
                book.reserved[slot] > 0,
                "slot {slot} needs chunk page {} of {needed} with no reservation \
                 left (rows {rows}) — chunked-admission accounting bug",
                book.tables[slot].len(),
            );
            let page = book.allocator.grow_reserved();
            book.reserved[slot] -= 1;
            book.tables[slot].push(page);
            self.metrics.page_grows += 1;
        }
        Ok(())
    }

    /// Reclaim one slot (every exit path runs through here): its unused
    /// growth reservations return to the pool, and its pages either
    /// **park** — clean retirement with the retained prefix pool on:
    /// the pages fully covered by the prompt enter the pool, the rest
    /// free — or release outright (`park: false`, the abort/cancel
    /// path, where prefill may never have written the pages).  No-op on
    /// the dense layout.
    pub fn release(&mut self, slot: usize, park: bool) {
        let Some(book) = &mut self.book else { return };
        let pages = std::mem::take(&mut book.tables[slot]);
        let prompt = std::mem::take(&mut book.prompts[slot]);
        let r = std::mem::take(&mut book.reserved[slot]);
        if r > 0 {
            book.allocator.unreserve(r);
        }
        book.shared[slot] = 0;
        book.prefilled[slot] = false;
        if pages.is_empty() {
            return;
        }
        if park && self.cfg.prefix_cache && self.cfg.share_prefixes {
            let page_size = book.allocator.page_size();
            book.pool.park(&prompt, pages, page_size, &mut book.allocator);
        } else {
            book.allocator.free(pages);
        }
    }

    /// The `(B, pages_per_slot)` i32 block table for the current slot
    /// assignments; unallocated tail entries point at the reserved
    /// garbage page.  With `for_append`, each slot's leading shared
    /// prefix entries are ALSO routed to the garbage page: `page_append`
    /// must never rewrite a donor's (or the retained pool's) live pages
    /// — the sharer's prefill rows for those positions are
    /// bit-identical anyway, and skipping the write is what makes
    /// prefix sharing copy-free — while the decode table keeps the real
    /// ids so gathers see the shared prefix.
    ///
    /// Panics on the dense layout (the engine never builds a block
    /// table there).
    pub fn block_table(&self, for_append: bool) -> Result<Tensor> {
        let book = self.book.as_ref().expect("block table on the dense layout");
        let pps = book.pages_per_slot;
        let mut bt = vec![RESERVED_PAGE as i32; self.width * pps];
        for (slot, pages) in book.tables.iter().enumerate() {
            let skip = if for_append { book.shared[slot] } else { 0 };
            for (j, &p) in pages.iter().enumerate().skip(skip) {
                bt[slot * pps + j] = p as i32;
            }
        }
        Tensor::from_i32(&[self.width, pps], bt)
    }

    /// Full cross-structure consistency check (property tests run it
    /// after every operation): allocator partition + ledger, prefix
    /// index vs allocator, per-slot reservation sum vs the ledger,
    /// every table page referenced.  Panics on the first violation.
    /// No-op on the dense layout.
    pub fn audit(&self) {
        let Some(book) = &self.book else { return };
        book.allocator.audit();
        book.pool.audit(&book.allocator, book.allocator.page_size());
        let mut reserved = 0usize;
        for (slot, table) in book.tables.iter().enumerate() {
            for &p in table {
                assert!(
                    p != RESERVED_PAGE && book.allocator.refcount(p) >= 1,
                    "slot {slot} references unallocated page {p}"
                );
            }
            assert!(
                book.shared[slot] <= table.len(),
                "slot {slot} shared count exceeds its table"
            );
            reserved += book.reserved[slot];
        }
        for adm in &book.pending {
            for &p in &adm.table {
                assert!(book.allocator.refcount(p) >= 1, "pending admission page {p} free");
            }
            reserved += adm.reserve;
        }
        assert_eq!(
            reserved,
            book.allocator.reserved_pages(),
            "per-slot reservations drifted from the ledger"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE: usize = 16;
    const MAX: usize = 160;

    fn mgr(num_pages: usize, cfg: KvCacheConfig) -> KvCacheManager {
        KvCacheManager::paged(4, MAX, num_pages, PAGE, MAX / PAGE, cfg)
    }

    fn plan(
        prompt: &[i32], max_new: usize, lazy: bool, donors: &[(Vec<i32>, Vec<u32>)],
    ) -> AdmitPlan {
        let cfg = KvCacheConfig { lazy_growth: lazy, ..Default::default() };
        mgr(41, cfg).plan(prompt, max_new, donors)
    }

    #[test]
    fn pages_needed_covers_lifetime_and_clamps() {
        let m = mgr(41, KvCacheConfig::default());
        assert_eq!(m.pages_needed(6, 8), 1, "14 rows fit one page");
        assert_eq!(m.pages_needed(30, 40), 5, "70 rows need 5 pages");
        assert_eq!(m.pages_needed(100, 500), 10, "clamped to max_len");
        assert_eq!(m.pages_needed(0, 4), 1, "empty prompt still holds a row");
    }

    #[test]
    fn oversized_requests_are_never_admissible() {
        // regression (PR-4 satellite): a pool smaller than one slot's
        // span must reject requests whose worst case exceeds it at
        // submit — queued, they would head-block the FIFO forever
        let m = mgr(3, KvCacheConfig::default()); // 2 usable
        assert!(m.ever_admissible(6, 8), "1-page request fits");
        assert!(m.ever_admissible(16, 16), "2-page request fits exactly");
        assert!(!m.ever_admissible(30, 40), "5-page worst case never fits");
        // the shipped geometry (40 usable, 10-page span) can admit any
        // single request — the guard exists for smaller provisioning
        let shipped = mgr(41, KvCacheConfig::default());
        assert!(shipped.ever_admissible(100, 10_000), "clamped to the span");
    }

    // ---- admission planner: lazy growth + copy-on-write sharing ----

    #[test]
    fn eager_plan_is_full_worst_case_up_front() {
        let p = plan(&[1; 20], 40, false, &[]);
        assert_eq!(p.fresh, 4, "ceil(60/16) pages allocated at admission");
        assert_eq!(p.reserve, 0, "eager reserves nothing");
        assert!(p.shared.is_empty());
        assert!(!p.cow_copy);
    }

    #[test]
    fn lazy_plan_grants_prompt_pages_plus_one_and_reserves_the_rest() {
        // prompt 20 → 2 pages; +1 decode page; worst case ceil(60/16)=4
        let p = plan(&[1; 20], 40, true, &[]);
        assert_eq!(p.fresh, 3);
        assert_eq!(p.reserve, 1);
        // total commitment always equals the worst case
        assert_eq!(p.fresh + p.reserve, plan(&[1; 20], 40, false, &[]).fresh);
    }

    #[test]
    fn lazy_plan_caps_the_decode_page_at_the_worst_case() {
        // prompt 10, budget 3: 13 rows fit the single prompt page — no
        // extra decode page, nothing to reserve
        let p = plan(&[1; 10], 3, true, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 0));
        // empty prompt still occupies one row
        let p = plan(&[], 4, true, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 0));
    }

    #[test]
    fn sharing_takes_only_full_common_prefix_pages() {
        let donor_prompt: Vec<i32> = (0..30).collect();
        let donor_table: Vec<u32> = vec![7, 8, 9]; // 2 prompt pages + decode page
        let donors = vec![(donor_prompt.clone(), donor_table)];
        // identical 30-token prompt: common=30 → 1 full page shared (the
        // page holding rows 16..29 is the boundary page — it will take
        // this slot's first decode writes, so it is copied, not shared
        let p = plan(&donor_prompt, 40, true, &donors);
        assert_eq!(p.shared, vec![7], "one full prefix page shared");
        assert!(p.cow_copy, "boundary page with matching rows was privatized");
        // commitment shrinks by exactly the shared pages
        let solo = plan(&donor_prompt, 40, true, &[]);
        assert_eq!(p.fresh + p.reserve + 1, solo.fresh + solo.reserve);
        // a 32-token twin shares both full pages and cow-copies nothing
        let two_pages: Vec<i32> = (0..32).collect();
        let donors = vec![(two_pages.clone(), vec![4, 5, 6])];
        let p = plan(&two_pages, 8, true, &donors);
        assert_eq!(p.shared, vec![4, 5]);
        assert!(!p.cow_copy, "prefix ends exactly on a page boundary");
    }

    #[test]
    fn sharing_never_reaches_a_page_either_side_could_write() {
        // donor prompt 20 (partial page 1), candidate identical: only
        // page 0 is fully inside both prompts
        let donor: Vec<i32> = (100..120).collect();
        let donors = vec![(donor.clone(), vec![3, 4, 5])];
        let p = plan(&donor, 16, true, &donors);
        assert_eq!(p.shared, vec![3], "partial pages are never shared");
        // unrelated prompt shares nothing
        let q = plan(&[9; 20], 16, true, &donors);
        assert!(q.shared.is_empty());
        assert!(!q.cow_copy);
        // sub-page common prefix: nothing shareable, and with zero
        // shared pages there is nothing to copy either — an ordinary
        // private admission, not a CoW event (metric stays meaningful)
        let mut near = donor.clone();
        near[10] = -1;
        let r = plan(&near, 16, true, &donors);
        assert!(r.shared.is_empty());
        assert!(!r.cow_copy);
    }

    #[test]
    fn best_donor_wins_and_same_wave_donors_are_usable() {
        let long: Vec<i32> = (0..32).collect();
        let donors = vec![
            (long[..16].to_vec(), vec![2, 3]), // 1 shareable page
            (long.clone(), vec![4, 5, 6]),     // 2 shareable pages
        ];
        let p = plan(&long, 8, true, &donors);
        assert_eq!(p.shared, vec![4, 5], "longest common prefix wins");
    }

    // ---- retained prefix pool: park / hit / evict lifecycle ----

    /// Admit + install one request into `slot`, asserting the gate
    /// opened.
    fn admit_install(m: &mut KvCacheManager, slot: usize, prompt: &[i32], max_new: usize) {
        assert!(m.admit(prompt, max_new), "admission starved unexpectedly");
        m.install(slot);
        m.audit();
    }

    #[test]
    fn full_prefix_hit_admits_with_zero_fresh_prompt_pages() {
        // THE satellite unit test: a prompt that fully hits the
        // retained pool allocates only its decode page — zero fresh
        // prompt pages.
        let mut m = mgr(41, KvCacheConfig::default());
        let prompt: Vec<i32> = (0..32).collect(); // exactly 2 pages
        admit_install(&mut m, 0, &prompt, 8);
        m.release(0, true); // retirement parks both prompt pages
        assert_eq!(m.retained_pages(), Some(2));
        let free_before = m.book.as_ref().unwrap().allocator.free_pages();
        admit_install(&mut m, 1, &prompt, 8);
        let free_after = m.book.as_ref().unwrap().allocator.free_pages();
        assert_eq!(
            free_before - free_after,
            1,
            "only the decode page was allocated fresh"
        );
        assert_eq!(m.metrics().prefix_hits, 1);
        assert_eq!(
            m.metrics().prefix_hit_tokens as usize,
            prompt.len(),
            "the whole prompt was served from the retained pool"
        );
        assert_eq!(m.retained_pages(), Some(0), "hit pages are outstanding again");
        // retirement of the sharer re-parks the same pages, no growth
        m.release(1, true);
        assert_eq!(m.retained_pages(), Some(2));
        m.audit();
    }

    #[test]
    fn pool_off_restores_pr4_free_at_retirement() {
        let cfg = KvCacheConfig { prefix_cache: false, ..Default::default() };
        let mut m = mgr(41, cfg);
        let prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &prompt, 8);
        m.release(0, true);
        assert_eq!(m.retained_pages(), Some(0), "nothing parks with the pool off");
        admit_install(&mut m, 1, &prompt, 8);
        assert_eq!(m.metrics().prefix_hits, 0);
        assert_eq!(m.metrics().shared_pages, 0, "no donor, nothing shared");
    }

    #[test]
    fn abort_release_never_parks() {
        let mut m = mgr(41, KvCacheConfig::default());
        let prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &prompt, 8);
        m.release(0, false); // cancel/abort: pages may be unwritten
        assert_eq!(m.retained_pages(), Some(0));
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        m.audit();
    }

    #[test]
    fn starved_admission_evicts_lru_but_never_live_pages() {
        // pool: 8 usable pages, span 4 pages (max_len 64, page 16)
        let mut m = KvCacheManager::paged(4, 64, 9, PAGE, 4, KvCacheConfig::default());
        // two retired prompts park 2 pages each (cold first, hot second)
        let cold: Vec<i32> = (0..32).collect();
        let hot: Vec<i32> = (100..132).collect();
        admit_install(&mut m, 0, &cold, 4);
        m.release(0, true);
        admit_install(&mut m, 0, &hot, 4);
        m.release(0, true);
        assert_eq!(m.retained_pages(), Some(4));
        // a hot-prefix admission re-shares 2 pages (touching the entry)
        admit_install(&mut m, 1, &hot, 4);
        assert_eq!(m.metrics().prefix_hits, 1);
        // unrelated demand (4 pages) vs 3 free: eviction must reclaim
        // from the LRU cold entry; the hot entry's pages are live
        // (slot 1 references them) and must survive untouched
        let stranger: Vec<i32> = (900..948).collect(); // 3 pages + budget
        assert!(m.admit(&stranger, 16), "eviction must unblock the admission");
        m.install(2);
        m.audit();
        assert!(m.metrics().evictions >= 1, "the cold entry was reclaimed");
        // the hot pages are still shared by slot 1 (refcounted, unharmed)
        assert_eq!(m.metrics().shared_pages, 2);
        // full reclamation after everything retires
        m.release(1, true);
        m.release(2, true);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        assert_eq!(m.reservations(), Some(0));
        m.audit();
    }

    #[test]
    fn admissible_now_counts_evictable_head_for_liveness() {
        // every usable page parked, nothing in flight: the head of the
        // queue MUST read as admissible (via eviction) or the engine
        // would idle with work queued
        let mut m = KvCacheManager::paged(2, 64, 9, PAGE, 4, KvCacheConfig::default());
        for (slot, base) in [(0usize, 0i32), (1, 500)] {
            let p: Vec<i32> = (base..base + 48).collect(); // 3 pages
            admit_install(&mut m, slot, &p, 16);
        }
        m.release(0, true);
        m.release(1, true);
        assert_eq!(m.retained_pages(), Some(6), "prompt pages parked");
        let stranger: Vec<i32> = (900..948).collect();
        let queued = [(stranger.as_slice(), 16usize)];
        let n = m.admissible_now(queued.iter().copied(), 1, 2);
        assert_eq!(n, 1, "head admissibility must see through the parked pool");
        // and the real gate agrees (sim/commit head exactness)
        assert!(m.admit(&stranger, 16));
        m.install(0);
        m.audit();
    }

    // ---- chunked-prefill admission (chunk_rows) ----

    #[test]
    fn chunked_plan_grants_first_chunk_and_reserves_the_rest() {
        // prompt 40 (3 pages), chunk 16 (1 page), budget 40: worst =
        // ceil(80/16) = 5 pages; admission grants only the chunk page
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let p = mgr(41, cfg).plan(&[1; 40], 40, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 4));
        // total commitment still equals the worst case
        assert_eq!(p.fresh + p.reserve, 5);
        // a prompt shorter than the chunk admits like one chunk
        let p = mgr(41, cfg).plan(&[1; 10], 3, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 0));
    }

    #[test]
    fn chunked_plan_keeps_shared_prefix_pages_in_the_table() {
        // the shared prefix (2 pages) exceeds the first chunk (1 page):
        // the table still holds every shared entry — sharing is
        // unchanged by chunking, only fresh-page timing moves
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let donor: Vec<i32> = (0..32).collect();
        let donors = vec![(donor.clone(), vec![4, 5, 6])];
        let p = mgr(41, cfg).plan(&donor, 40, &donors);
        assert_eq!(p.shared, vec![4, 5], "chunking must not shrink sharing");
        assert_eq!(p.fresh, 0, "shared pages already cover the first chunk");
        // commitment unchanged vs the monolithic plan
        let mono = mgr(41, KvCacheConfig::default()).plan(&donor, 40, &donors);
        assert_eq!(
            p.shared.len() + p.fresh + p.reserve,
            mono.shared.len() + mono.fresh + mono.reserve
        );
    }

    #[test]
    fn grow_prefill_converts_reservations_chunk_by_chunk() {
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let mut m = mgr(41, cfg);
        let prompt: Vec<i32> = (0..40).collect(); // 3 prompt pages
        admit_install(&mut m, 0, &prompt, 40);
        assert_eq!(m.reservations(), Some(4));
        // chunk walk: 16 rows covered at admission, then 32, then 40
        m.grow_prefill(0, 16).unwrap();
        assert_eq!(m.reservations(), Some(4), "chunk 1 already covered");
        m.grow_prefill(0, 32).unwrap();
        assert_eq!(m.reservations(), Some(3));
        m.grow_prefill(0, 40).unwrap();
        assert_eq!(m.reservations(), Some(2), "prompt fully paged");
        m.audit();
        // decode growth continues from the same ledger
        m.grow_to(0, 48).unwrap();
        assert_eq!(m.reservations(), Some(1));
        // mid-prefill release (the cancel path) reclaims pages AND the
        // remaining reservations
        m.release(0, false);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        assert_eq!(m.reservations(), Some(0));
        m.audit();
    }

    #[test]
    fn chunked_admissible_now_matches_the_chunked_gate() {
        // head-exactness must hold under chunked admission arithmetic
        // too: the sim and the gate share plan(), so a pool with room
        // for one first-chunk grant admits exactly one
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let mut m = KvCacheManager::paged(2, 64, 5, PAGE, 4, cfg); // 4 usable
        let big: Vec<i32> = (0..48).collect(); // worst 4 pages
        let queued = [(big.as_slice(), 16usize)];
        let n = m.admissible_now(queued.iter().copied(), 1, 2);
        assert_eq!(n, 1);
        assert!(m.admit(&big, 16), "sim and gate agree");
        m.install(0);
        m.audit();
    }

    #[test]
    fn chunked_sharing_waits_for_donor_prefill() {
        // regression (PR-7): a mid-chunk slot's pages hold no KV — it
        // must not donate CoW prefixes until its prefill commits, or a
        // sharer can read garbage / orphan the page under requeue
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let mut m = mgr(41, cfg);
        let prompt: Vec<i32> = (0..32).collect(); // 2 full pages
        admit_install(&mut m, 0, &prompt, 8);
        // donor admitted but unprefilled: an identical prompt shares 0
        admit_install(&mut m, 1, &prompt, 8);
        assert_eq!(m.metrics().shared_pages, 0, "unwritten donor must not share");
        m.release(1, false);
        // prefill commits → the same admission now shares both pages
        m.mark_prefilled(0);
        admit_install(&mut m, 1, &prompt, 8);
        assert_eq!(m.metrics().shared_pages, 2, "written donor shares normally");
        // same-wave pending admissions never donate under chunking
        assert!(m.admit(&prompt, 8), "pending admission");
        assert!(m.admit(&prompt, 8), "second of the wave");
        assert_eq!(
            m.metrics().shared_pages,
            2 + 2 + 2,
            "both wave members shared only from the prefilled live donor"
        );
        m.install(2);
        m.install(3);
        m.audit();
        // the monolithic planner ignores the flag entirely (PR-6 parity)
        let mut mono = mgr(41, KvCacheConfig::default());
        admit_install(&mut mono, 0, &prompt, 8);
        admit_install(&mut mono, 1, &prompt, 8);
        assert_eq!(mono.metrics().shared_pages, 2, "monolithic shares unprefilled");
    }

    #[test]
    fn conservation_across_a_mixed_wave() {
        let mut m = mgr(21, KvCacheConfig::default()); // 20 usable
        let shared_prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &shared_prompt, 40);
        admit_install(&mut m, 1, &shared_prompt, 8); // shares 2 pages
        admit_install(&mut m, 2, &[7; 10], 4);
        assert!(m.metrics().shared_pages >= 2);
        // grow slot 0 across a boundary
        m.grow_to(0, 48).unwrap();
        assert!(m.metrics().page_grows >= 1);
        m.audit();
        // retire in donor-first order; pages park, conservation holds
        m.release(0, true);
        m.release(1, true);
        m.release(2, true);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable, "free + retained covers the pool");
        assert_eq!(m.reservations(), Some(0));
        m.audit();
    }

    #[test]
    fn preload_prefix_parks_pages_and_serves_the_next_admission() {
        let mut m = mgr(41, KvCacheConfig::default());
        let prompt: Vec<i32> = (0..40).collect(); // 2 full pages + remainder
        assert_eq!(m.preload_prefix(&prompt), 2, "both full pages parked");
        assert_eq!(m.retained_pages(), Some(2));
        m.audit();
        // idempotent: the pool already covers this prefix
        assert_eq!(m.preload_prefix(&prompt), 0);
        // the next admission of the same prompt shares the warmed pages
        admit_install(&mut m, 0, &prompt, 8);
        assert_eq!(m.metrics().prefix_hits, 1, "admission hit the warmed entry");
        assert!(m.metrics().prefix_hit_tokens >= 32);
        m.release(0, true);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable, "conservation holds after retirement");
        m.audit();
    }

    #[test]
    fn preload_prefix_respects_headroom_retention_and_layout() {
        // sub-page prompts install nothing
        let mut m = mgr(41, KvCacheConfig::default());
        assert_eq!(m.preload_prefix(&[1; 10]), 0, "no full page to park");
        // never competes with growth reservations: lazy slot holds the
        // pool's headroom hostage, the preload declines instead
        let mut small = mgr(5, KvCacheConfig::default()); // 4 usable
        admit_install(&mut small, 0, &[7; 20], 30); // 3 fresh + 1 reserved
        assert_eq!(small.reservations(), Some(1));
        let long: Vec<i32> = (100..148).collect(); // wants 3 pages
        assert_eq!(small.preload_prefix(&long), 0, "unreserved headroom too small");
        small.audit();
        // retention off / dense layout: structurally a no-op
        let cfg = KvCacheConfig { prefix_cache: false, ..Default::default() };
        assert_eq!(mgr(41, cfg).preload_prefix(&[1; 40]), 0);
        let mut dense = KvCacheManager::dense(4, MAX, KvCacheConfig::default());
        assert_eq!(dense.preload_prefix(&[1; 40]), 0);
    }
}
