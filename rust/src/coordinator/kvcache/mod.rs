//! The KV-cache manager: every paged-cache *policy* decision behind one
//! narrow API, so the engine stays pure batch orchestration.
//!
//! PRs 3–4 grew the paged KV cache (block tables, lazy growth,
//! copy-on-write prefix sharing) inside `coordinator/engine.rs`, tangled
//! with artifact scheduling.  This module is that policy carved out:
//!
//! * [`pagetable`] — the refcounted free-list [`PageAllocator`] with the
//!   reservation ledger (lazy growth) and the parked-page state
//!   (retained prefixes);
//! * [`prefix_pool`](self) *(private)* — the token-indexed LRU pool of
//!   retained prompt prefixes;
//! * [`KvCacheManager`] — the façade the engine drives:
//!   - [`admit`](KvCacheManager::admit) / [`install`](KvCacheManager::install)
//!     — plan and commit one admission (fresh pages + growth
//!     reservation, net of prefix pages shared from in-flight donors
//!     *or* the retained pool), then bind it to a batch slot;
//!   - [`grow_to`](KvCacheManager::grow_to) — convert reservations into
//!     real pages as a slot's position crosses page boundaries;
//!   - [`release`](KvCacheManager::release) — retire or abort a slot:
//!     reservations return to the pool, and on clean retirement the
//!     pages fully covered by the prompt are **parked** in the retained
//!     prefix pool instead of freed.
//!
//! **Retention lifecycle.**  A hot system prompt's KV pages survive idle
//! gaps: retirement parks them (pool adopts the slot's reference),
//! admission probes the pool exactly like it probes in-flight donors
//! and re-shares hits copy-on-write through the PR-4 refcount
//! machinery, and a lazy LRU evictor reclaims parked pages only when an
//! admission would otherwise starve.  The allocator-level partition
//! `free + outstanding + retained == usable` and the no-deadlock
//! guarantee `free >= reserved` hold at every step
//! (`prop_prefix_pool_conservation`), and a page with a live
//! block-table reference is never evicted.
//!
//! **Two-tier hierarchy (PR 9).**  The device pool is tier 0 of a
//! memory hierarchy; [`host_tier`] owns tier 1, a byte-capped host
//! store, and is the only path KV page bytes take device↔host.  Three
//! consumers ride on it:
//!
//! * **Overcommit + preemptive swap** — with
//!   [`KvCacheConfig::overcommit_factor`] ` > 1.0` the reservation
//!   ledger may promise more growth than the free list holds
//!   (`reserved <= floor(free * factor)` at admission).  When growth
//!   actually runs dry, the engine picks a victim
//!   ([`KvCacheManager::pick_victim`]: youngest-admitted decode first,
//!   never a CoW donor with live sharers), swaps its private pages to
//!   the host tier ([`KvCacheManager::swap_out`]) and requeues it; on
//!   re-admission ([`KvCacheManager::swap_in`]) seed-replay regenerates
//!   its tokens bit-identically to the unpreempted run.
//! * **Prefix spill** — admission pressure *demotes* retained prefix
//!   entries to the host tier (`PrefixPool::spill_pages`) instead of
//!   discarding them, and [`KvCacheManager::promote_for`] re-promotes
//!   the queue head's prefix on a hit.
//! * **Cluster prefix export/warm** — [`KvCacheManager::export_prefix`]
//!   stages a retained prefix's pages into the tier (the real engine
//!   captures the actual KV bytes) for the cluster prefix store, and
//!   [`KvCacheManager::warm_prefix_host`] ingests a warm-start payload
//!   host-side and promotes it to the device on demand.
//!
//! At `overcommit_factor: 1.0` with a zero-capacity tier every one of
//! these paths is inert and the manager is bit-identical to the PR-8
//! single-tier baseline.
//!
//! The manager is pure bookkeeping — no device buffers, no runtime
//! calls — so the whole policy is unit- and property-testable without
//! artifacts, and the Python protocol twin
//! (`python/tests/test_paged_serving_protocol.py`) mirrors it
//! operation for operation.

pub mod host_tier;
pub mod pagetable;
mod prefix_pool;

use std::collections::VecDeque;

use anyhow::Result;

use crate::tensor::Tensor;
use host_tier::{HostOp, HostTier, HostTierConfig, HostTierStats, PrefixKv};
use pagetable::{PageAllocator, RESERVED_PAGE};
use prefix_pool::PrefixPool;

/// Which on-device layout carries the live KV state (see the engine's
/// module docs for the buffer shapes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// Dense per-slot caches `(L, B, Tmax, nh, dh)`, padded to the
    /// worst-case `max_len` — the compatibility/equivalence baseline.
    Dense,
    /// Shared page pools `(L, num_pages, page_size, nh, dh)` addressed
    /// through per-slot block tables; memory tracks actual contexts.
    Paged,
}

/// Cache-policy knobs (the engine copies these out of `EngineConfig`).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Lazy page growth: admit with prompt pages + one decode page and
    /// grow from the reservation ledger as `pos` advances.  `false`
    /// restores eager worst-case-at-admission allocation (PR 3).
    pub lazy_growth: bool,
    /// Copy-on-write prompt-prefix sharing across in-flight block
    /// tables (PR 4).
    pub share_prefixes: bool,
    /// Retained prefix pool: park prompt-prefix pages at retirement and
    /// serve later admissions from them (LRU-evicted under pressure).
    /// Requires `share_prefixes`; `false` restores the PR-4 baseline
    /// where prefix pages die with their last block-table reference.
    pub prefix_cache: bool,
    /// Chunked-prefill admission (`Some(chunk_rows)`): a lazy admission
    /// grants only the pages covering the prompt's *first chunk*
    /// (`min(prompt_len, chunk_rows)` rows, never fewer than the shared
    /// prefix pages) and reserves the rest of the worst case; chunk
    /// advances convert reservations through
    /// [`KvCacheManager::grow_prefill`].  `None` (default) keeps the
    /// monolithic prompt-pages-plus-decode-page grant.  Prefix-pool
    /// probing is unchanged, but live CoW donors are restricted to
    /// slots whose prefill has *completed*
    /// ([`KvCacheManager::mark_prefilled`]): a mid-chunk slot's pages
    /// hold no KV yet, and chunking breaks the monolithic guarantee
    /// that a whole admission wave prefills (or requeues) atomically —
    /// a sharer could outrun or outlive an unwritten donor and read
    /// garbage or permanently orphan the shared page.
    pub chunk_rows: Option<usize>,
    /// Reservation-ledger overcommit watermark: admission may promise
    /// growth up to `floor(free * overcommit_factor)` pages while only
    /// `free` exist (fresh pages never overcommit — they must exist at
    /// admission).  `1.0` (default) is the strict PR-8 gate, where
    /// growth can never run dry; above it the engine must be prepared
    /// to preempt ([`KvCacheManager::pick_victim`] /
    /// [`KvCacheManager::swap_out`]) when [`KvCacheManager::grow_to`]
    /// would starve.
    pub overcommit_factor: f64,
    /// Host tier (tier 1) geometry; `capacity_bytes: 0` (default)
    /// disables the tier and every swap/spill/warm path with it.
    pub host_tier: HostTierConfig,
}

impl Default for KvCacheConfig {
    fn default() -> Self {
        KvCacheConfig {
            lazy_growth: true,
            share_prefixes: true,
            prefix_cache: true,
            chunk_rows: None,
            overcommit_factor: 1.0,
            host_tier: HostTierConfig::default(),
        }
    }
}

/// Monotonic counters of the manager's policy machinery (mirrored into
/// `EngineMetrics` by the engine after every tick).
#[derive(Clone, Debug, Default)]
pub struct KvMetrics {
    /// Pages allocated lazily mid-flight, one per page-boundary
    /// crossing, out of the slot's admission-time reservation.
    pub page_grows: u64,
    /// Block-table entries admitted as references to a donor's (or the
    /// retained pool's) prompt-prefix pages instead of fresh
    /// allocations.
    pub shared_pages: u64,
    /// Copy-on-write events: admissions whose common prefix ran into a
    /// page the appended decode row could write, so that page was made
    /// private and the slot's own `page_append` performed the copy.
    pub cow_copies: u64,
    /// Admissions that re-shared at least one page from the retained
    /// prefix pool.
    pub prefix_hits: u64,
    /// Prompt tokens whose KV was served from the retained pool instead
    /// of being recomputed and re-stored (full pages only).
    pub prefix_hit_tokens: u64,
    /// Retained pages reclaimed by the LRU evictor because an admission
    /// would otherwise have starved.
    pub evictions: u64,
}

/// One planned admission: how much of the worst-case page need
/// (`ceil(min(prompt + max_new, max_len) / page_size)`) is shared from
/// a donor or the retained pool, allocated now, or reserved for lazy
/// growth.
#[derive(Clone, Debug, PartialEq, Eq)]
struct AdmitPlan {
    /// Donor prefix pages the new block table will reference
    /// (refcounted; always fully covered by the common token prefix of
    /// both prompts, so neither side ever writes them).
    shared: Vec<u32>,
    /// Pages to allocate fresh at admission.
    fresh: usize,
    /// Worst-case growth budget to reserve (0 under eager admission).
    reserve: usize,
    /// The common prefix extended into a page the appended decode row
    /// could write: that page was made private instead of shared, and
    /// the slot's own `page_append` write performs the copy (the
    /// copy-on-write event).
    cow_copy: bool,
    /// `Some((entry, pages))` when the winning donor was a retained
    /// prefix-pool entry rather than an in-flight slot.
    pool_hit: Option<(usize, usize)>,
}

/// An admission committed in the allocator but not yet bound to a batch
/// slot (the refill loop learns slot indices only after its gate ran).
#[derive(Clone, Debug)]
struct Admission {
    table: Vec<u32>,
    shared: usize,
    reserve: usize,
    prompt: Vec<i32>,
}

/// Paged-layout policy state (block tables + page ownership + the
/// retained prefix pool).
#[derive(Debug)]
struct PagedBook {
    /// Free-list over the pool's page ids (page 0 reserved).
    allocator: PageAllocator,
    /// Retained prefix index (LRU-evicted parked pages).
    pool: PrefixPool,
    /// Block-table width (pages addressable per slot).
    pages_per_slot: usize,
    /// Per-slot page ids, in position order; empty for free slots.  The
    /// leading `shared[slot]` entries are references to a donor's or
    /// the pool's prefix pages (refcounted, never written by this
    /// slot).
    tables: Vec<Vec<u32>>,
    /// Per-slot admitted prompt (sharing-donor lookup + parking key).
    prompts: Vec<Vec<i32>>,
    /// Per-slot remaining growth budget, mirrored in the allocator's
    /// reservation ledger.
    reserved: Vec<usize>,
    /// Per-slot count of leading block-table entries shared from a
    /// donor (`page_append` routes these chunks to the garbage page).
    shared: Vec<usize>,
    /// Per-slot "prompt KV fully written" flag
    /// ([`KvCacheManager::mark_prefilled`]).  Only consulted under
    /// chunked admission, where it gates CoW donor eligibility; the
    /// monolithic paths keep their PR-6 behaviour bit-for-bit.
    prefilled: Vec<bool>,
    /// Admissions committed by [`KvCacheManager::admit`] awaiting their
    /// [`KvCacheManager::install`] slot binding, in FIFO order.
    pending: VecDeque<Admission>,
    /// Host tier (tier 1): pinned swap victims + demoted prefix pages.
    tier: HostTier,
    /// Per-slot admission stamp ([`PagedBook::clock`] at install; 0 for
    /// free slots) — the deterministic age order the victim policy
    /// ranks by.
    seq: Vec<u64>,
    /// Monotonic admission clock feeding `seq`.
    clock: u64,
}

/// The KV-cache policy façade (see the module docs).
#[derive(Debug)]
pub struct KvCacheManager {
    /// `None` on the dense layout — every method degrades to a no-op /
    /// always-admit there, so the engine drives one code path.
    book: Option<PagedBook>,
    cfg: KvCacheConfig,
    width: usize,
    max_len: usize,
    metrics: KvMetrics,
}

impl KvCacheManager {
    /// Manager for the dense layout: no page accounting, every request
    /// admissible, every policy call a no-op.
    pub fn dense(width: usize, max_len: usize, cfg: KvCacheConfig) -> Self {
        KvCacheManager { book: None, cfg, width, max_len, metrics: KvMetrics::default() }
    }

    /// Manager for the paged layout with the given pool geometry
    /// (validated upstream against the artifact manifest).
    pub fn paged(
        width: usize, max_len: usize, num_pages: usize, page_size: usize,
        pages_per_slot: usize, mut cfg: KvCacheConfig,
    ) -> Self {
        if cfg.prefix_cache && !cfg.share_prefixes {
            // retention rides on the CoW sharing machinery: with
            // sharing off there is no path that could re-share a
            // parked page, so normalize instead of silently no-opping
            log::info!(
                "kvcache: prefix_cache requires share_prefixes — \
                 retention disabled (PR-4 baseline semantics)"
            );
            cfg.prefix_cache = false;
        }
        let mut allocator = PageAllocator::new(num_pages, page_size);
        allocator.set_overcommit(cfg.overcommit_factor);
        KvCacheManager {
            book: Some(PagedBook {
                allocator,
                pool: PrefixPool::default(),
                pages_per_slot,
                tables: vec![Vec::new(); width],
                prompts: vec![Vec::new(); width],
                reserved: vec![0; width],
                shared: vec![0; width],
                prefilled: vec![false; width],
                pending: VecDeque::new(),
                tier: HostTier::new(cfg.host_tier),
                seq: vec![0; width],
                clock: 0,
            }),
            cfg,
            width,
            max_len,
            metrics: KvMetrics::default(),
        }
    }

    /// Which layout this manager books for.
    pub fn layout(&self) -> KvLayout {
        if self.book.is_some() { KvLayout::Paged } else { KvLayout::Dense }
    }

    /// Policy counters (monotonic; the engine mirrors them into
    /// `EngineMetrics`).
    pub fn metrics(&self) -> &KvMetrics {
        &self.metrics
    }

    /// Reclaimable / total usable pool pages (`None` on the dense
    /// layout).  "Reclaimable" counts the free list — growth headroom
    /// reserved by in-flight slots included — plus the retained prefix
    /// pool, which the LRU evictor returns on demand; after a full
    /// drain it equals the usable pool (the conservation check the
    /// reclamation tests pin).
    pub fn page_budget(&self) -> Option<(usize, usize)> {
        self.book.as_ref().map(|b| {
            (
                b.allocator.free_pages() + b.allocator.retained_pages(),
                b.allocator.usable_pages(),
            )
        })
    }

    /// Free pages promised to in-flight slots for lazy growth (`None`
    /// on the dense layout; 0 after a full drain).
    pub fn reservations(&self) -> Option<usize> {
        self.book.as_ref().map(|b| b.allocator.reserved_pages())
    }

    /// Pages currently parked in the retained prefix pool (`None` on
    /// the dense layout).
    pub fn retained_pages(&self) -> Option<usize> {
        self.book.as_ref().map(|b| b.allocator.retained_pages())
    }

    /// Rows per pool page (`None` on the dense layout).
    pub fn page_size(&self) -> Option<usize> {
        self.book.as_ref().map(|b| b.allocator.page_size())
    }

    /// Warm-start the retained prefix pool with `prompt`'s full-page
    /// prefix, as if a slot with that prompt had just retired (the host
    /// prefix store's download path, see `coordinator::cluster`).
    /// Pages come from the *unreserved* free pool only — a preload
    /// never competes with committed growth reservations and never
    /// evicts locally-warmed entries — and they enter the pool through
    /// the same [`PrefixPool::park`] path retirement uses, so dedup
    /// against existing entries, LRU eviction, and the allocator's
    /// conservation ledger all hold unchanged.  Returns the pages
    /// actually added to the retained pool: 0 on the dense layout, with
    /// retention off, when the pool already covers the prefix, or when
    /// free headroom is insufficient.
    pub fn preload_prefix(&mut self, prompt: &[i32]) -> usize {
        if !self.cfg.prefix_cache {
            return 0;
        }
        let Some(book) = &mut self.book else { return 0 };
        let page_size = book.allocator.page_size();
        let full_pages = prompt.len() / page_size;
        if full_pages == 0 {
            return 0;
        }
        if book
            .pool
            .lookup(prompt, page_size)
            .is_some_and(|h| h.pages >= full_pages)
        {
            return 0;
        }
        let Some(pages) = book.allocator.alloc(full_pages) else {
            return 0;
        };
        let before = book.allocator.retained_pages();
        // park() dedups against overlapping entries and frees whatever
        // it does not keep — the retained delta is what the download
        // actually installed
        book.pool.park(
            &prompt[..full_pages * page_size],
            pages,
            page_size,
            &mut book.allocator,
        );
        book.allocator.retained_pages() - before
    }

    /// Worst-case pages a request needs over its whole lifetime
    /// (prompt + generation budget, clamped to the context span) — what
    /// eager admission allocates and lazy admission commits (allocated
    /// + reserved).  0 on the dense layout.
    pub fn pages_needed(&self, prompt_len: usize, max_new: usize) -> usize {
        match &self.book {
            None => 0,
            Some(b) => {
                let rows = (prompt_len.max(1) + max_new).min(self.max_len);
                b.allocator.pages_for(rows)
            }
        }
    }

    /// Whether a request of this shape could EVER be admitted: its
    /// worst-case commitment must fit the whole usable pool (neither
    /// prefix sharing nor retention is assumed — donors are transient
    /// and retained pages evict).  `false` means reject at submit, or
    /// the request would head-block the FIFO queue forever.
    pub fn ever_admissible(&self, prompt_len: usize, max_new: usize) -> bool {
        match &self.book {
            None => true,
            Some(b) => {
                self.pages_needed(prompt_len, max_new) <= b.allocator.usable_pages()
            }
        }
    }

    /// Plan one admission against the current donors: in-flight slots,
    /// admissions pending installation, the caller's extra simulated
    /// donors, and — strictly last, so live donors win ties — the
    /// retained prefix pool.  Sharing is restricted to pages *fully
    /// covered* by the common token prefix: any page a decode row could
    /// land in (positions `>= prompt_len` for either side) must be
    /// private, because pool pages are only ever written through a
    /// slot's own block-table entry.  The boundary page the common
    /// prefix runs into is therefore copied — by the admission's own
    /// `page_append` write, not a device copy — exactly when it would
    /// otherwise be written (`cow_copy`).
    fn plan(
        &self, prompt: &[i32], max_new: usize, extra: &[(Vec<i32>, Vec<u32>)],
    ) -> AdmitPlan {
        let book = self.book.as_ref().expect("plan on the dense layout");
        let page_size = book.allocator.page_size();
        let plen = prompt.len().max(1);
        let worst = (plen + max_new).min(self.max_len).div_ceil(page_size);
        let prompt_pages = plen.div_ceil(page_size);
        let mut shared: Vec<u32> = Vec::new();
        let mut best_common = 0usize;
        let mut pool_hit = None;
        if self.cfg.share_prefixes {
            // Chunked admission shares only from prefill-COMPLETE live
            // donors, and never from same-wave pending admissions: an
            // unwritten donor's pages hold no KV, and without the
            // monolithic wave's atomic prefill-or-requeue a sharer can
            // outrun or outlive the donor (see `chunk_rows` docs).
            let chunked = self.cfg.chunk_rows.is_some();
            let live = book
                .tables
                .iter()
                .zip(&book.prompts)
                .zip(&book.prefilled)
                .filter(move |((t, _), &done)| !t.is_empty() && (!chunked || done))
                .map(|((t, p), _)| (p.as_slice(), t.as_slice()));
            let pend = book
                .pending
                .iter()
                .filter(move |_| !chunked)
                .map(|a| (a.prompt.as_slice(), a.table.as_slice()));
            let sim = extra.iter().map(|(p, t)| (p.as_slice(), t.as_slice()));
            // NOTE: this scoring (common tokens → full shared pages →
            // best by (pages, common)) must stay in lockstep with
            // `PrefixPool::lookup` — the pool is probed "exactly like a
            // donor", and a divergence would rank the two differently
            for (donor_prompt, donor_table) in live.chain(pend).chain(sim) {
                let common = prompt
                    .iter()
                    .zip(donor_prompt.iter())
                    .take_while(|(a, b)| a == b)
                    .count();
                // full pages inside BOTH prompts (common <= both
                // lengths); a donor's table always covers its own
                // prompt pages
                let n = (common / page_size).min(donor_table.len());
                if n > shared.len() || (n == shared.len() && common > best_common) {
                    shared = donor_table[..n].to_vec();
                    best_common = common;
                }
            }
            if self.cfg.prefix_cache {
                if let Some(hit) = book.pool.lookup(prompt, page_size) {
                    if hit.pages > shared.len()
                        || (hit.pages == shared.len() && hit.common > best_common)
                    {
                        shared = book.pool.entry_pages(hit.idx)[..hit.pages].to_vec();
                        best_common = hit.common;
                        pool_hit = Some((hit.idx, hit.pages));
                    }
                }
            }
        }
        let n_share = shared.len();
        debug_assert!(n_share <= prompt_pages);
        // lazy: prompt pages + one decode page (capped at the worst
        // case); eager: the full worst case, nothing reserved; chunked
        // lazy: only the first chunk's pages (never fewer than the
        // shared prefix — those entries live in the table from day one)
        let table_len = match (self.cfg.lazy_growth, self.cfg.chunk_rows) {
            (false, _) => worst,
            (true, None) => (prompt_pages + 1).min(worst),
            (true, Some(chunk)) => {
                let chunk_pages = plen.min(chunk.max(1)).div_ceil(page_size);
                chunk_pages.max(n_share).min(worst)
            }
        };
        AdmitPlan {
            fresh: table_len - n_share,
            reserve: worst - table_len,
            // only a real sharing admission can copy-on-write: the
            // boundary page is "copied" when the common prefix extends
            // past the last fully-shared page (sub-page overlaps with
            // no shared pages are ordinary private admissions)
            cow_copy: n_share > 0 && best_common > n_share * page_size,
            shared,
            pool_hit,
        }
    }

    /// Requests the scheduler may admit *this* tick: the FIFO prefix of
    /// `queued` (pairs of prompt + decode budget, `total` long) whose
    /// page commitments — fresh + reserved, net of shareable prefix
    /// pages — fit the *unreserved* pool.  The **head** additionally
    /// counts the LRU-evictable retained pages its admission could
    /// reclaim, in exactly the arithmetic [`Self::admit`] commits —
    /// this head-exactness is load-bearing: if the simulation said 0
    /// where the real gate would admit, a queue whose pages are all
    /// parked would read as page-starved forever and the engine's
    /// liveness guard would fire.  Later candidates use the plain
    /// unreserved budget (conservative: the head's admission already
    /// guarantees the tick makes progress).
    pub fn admissible_now<'a, I>(&self, queued: I, total: usize, empty: usize) -> usize
    where
        I: Iterator<Item = (&'a [i32], usize)>,
    {
        let Some(book) = &self.book else { return total };
        let limit = total.min(empty);
        if limit == 0 {
            return 0; // steady-state decode tick: skip the donor scan
        }
        // mirror the allocator's two-constraint overcommit gate exactly
        // (see `admission_budget`): fresh pages must exist now, while
        // reservations fit the inflated watermark.  At factor 1.0 this
        // collapses to the PR-8 `need <= unreserved` arithmetic.
        let factor = book.allocator.overcommit();
        let budget_of = |free: usize, reserved: usize| {
            ((free as f64 * factor).floor() as usize).saturating_sub(reserved)
        };
        let mut free = book.allocator.free_pages();
        let mut reserved = book.allocator.reserved_pages();
        let mut extra: Vec<(Vec<i32>, Vec<u32>)> = Vec::new();
        let mut admissible = 0usize;
        for (prompt, max_new) in queued.take(limit) {
            let plan = self.plan(prompt, max_new, &extra);
            let need = plan.fresh + plan.reserve;
            let short = plan
                .fresh
                .saturating_sub(free)
                .max(need.saturating_sub(budget_of(free, reserved)));
            let fits = short == 0
                || (admissible == 0
                    && short
                        <= book.pool.evictable_pages(&book.allocator, plan.pool_hit));
            if !fits {
                break;
            }
            // a head admitted through eviction reclaims `short` pages
            // into the free list before the gate consumes its fresh
            free = (free + short).saturating_sub(plan.fresh);
            reserved += plan.reserve;
            admissible += 1;
            if self.cfg.share_prefixes && self.cfg.chunk_rows.is_none() {
                // page ids are placeholders — only the table LENGTH
                // matters for later candidates' share planning.  Skipped
                // under chunked admission, where same-wave donors are
                // ineligible (their pages are unwritten) — the sim must
                // mirror the gate's arithmetic exactly
                let len = plan.shared.len() + plan.fresh;
                extra.push((prompt.to_vec(), vec![RESERVED_PAGE; len]));
            }
        }
        admissible
    }

    /// Plan and **commit** one admission: allocate its fresh pages,
    /// reserve its growth budget, take references on its shared prefix
    /// pages, and queue the built block table for [`Self::install`].
    /// When the unreserved pool cannot cover the need, the LRU evictor
    /// reclaims retained pages first (pinning the planned shares so
    /// they survive) — but only when eviction actually covers the
    /// deficit: a starved admission must not trash retained prefixes
    /// it cannot be unblocked by.  `false` means genuine starvation —
    /// the caller stops its refill so FIFO order holds.  Always `true`
    /// on the dense layout.
    pub fn admit(&mut self, prompt: &[i32], max_new: usize) -> bool {
        if self.book.is_none() {
            return true;
        }
        let plan = self.plan(prompt, max_new, &[]);
        let book = self.book.as_mut().expect("checked above");
        let need = plan.fresh + plan.reserve;
        // two-constraint overcommit gate (the sim in `admissible_now`
        // mirrors this arithmetic term for term): fresh pages must
        // exist now, reservations fit the inflated watermark
        let short = |a: &PageAllocator| {
            plan.fresh
                .saturating_sub(a.free_pages())
                .max(need.saturating_sub(a.admission_budget()))
        };
        if short(&book.allocator) > 0 {
            // pin the planned shares: LRU reclamation must not take the
            // very pages this admission is about to reference (and with
            // the pins baked into the refcounts, the evictable count is
            // exactly what spill_pages could reclaim)
            for &p in &plan.shared {
                book.allocator.retain(p);
            }
            let deficit = short(&book.allocator);
            if deficit <= book.pool.evictable_pages(&book.allocator, None) {
                // demote-don't-discard: the reclaimed prefixes drop to
                // the host tier where capacity allows
                let evicted =
                    book.pool.spill_pages(deficit, &mut book.allocator, &mut book.tier);
                self.metrics.evictions += evicted as u64;
            }
            // else: genuine starvation — evicting the reclaimable few
            // would trash retained prefixes without unblocking anything
            for &p in &plan.shared {
                book.allocator.release(p);
            }
            if short(&book.allocator) > 0 {
                return false;
            }
        }
        let fresh = book
            .allocator
            .admit(plan.fresh, plan.reserve)
            .expect("admission was gated on the overcommit budget");
        for &p in &plan.shared {
            book.allocator.retain(p);
        }
        self.metrics.shared_pages += plan.shared.len() as u64;
        self.metrics.cow_copies += plan.cow_copy as u64;
        if let Some((_, pages)) = plan.pool_hit {
            self.metrics.prefix_hits += 1;
            self.metrics.prefix_hit_tokens +=
                (pages * book.allocator.page_size()) as u64;
            // re-look the entry up rather than trusting the planned
            // index: eviction above may have compacted the index
            if let Some(hit) = book.pool.lookup(prompt, book.allocator.page_size()) {
                book.pool.touch(hit.idx);
            }
        }
        let shared_n = plan.shared.len();
        let mut table = plan.shared;
        table.extend(fresh);
        book.pending.push_back(Admission {
            table,
            shared: shared_n,
            reserve: plan.reserve,
            prompt: prompt.to_vec(),
        });
        true
    }

    /// Bind the oldest committed-but-unbound admission to batch slot
    /// `slot` (the refill loop learns indices only after its admission
    /// gate ran; FIFO order matches by construction).  No-op on the
    /// dense layout.
    pub fn install(&mut self, slot: usize) {
        let Some(book) = &mut self.book else { return };
        let adm = book.pending.pop_front().expect("install without a pending admit");
        book.tables[slot] = adm.table;
        book.shared[slot] = adm.shared;
        book.reserved[slot] = adm.reserve;
        book.prompts[slot] = adm.prompt;
        book.prefilled[slot] = false;
        book.clock += 1;
        book.seq[slot] = book.clock;
    }

    /// Record that `slot`'s prompt KV is fully written (the engine calls
    /// this when the slot's prefill commits).  Under chunked admission
    /// this is what makes the slot eligible as a CoW prefix donor; the
    /// monolithic planner ignores the flag.  No-op on the dense layout.
    pub fn mark_prefilled(&mut self, slot: usize) {
        if let Some(book) = &mut self.book {
            book.prefilled[slot] = true;
        }
    }

    /// Admissions committed but not yet bound to a slot (0 between
    /// refill waves — asserted by the engine and the property tests).
    pub fn pending_installs(&self) -> usize {
        self.book.as_ref().map_or(0, |b| b.pending.len())
    }

    /// Lazy growth: extend `slot`'s block table until it covers a KV
    /// write at `pos`, converting one admission-time reservation per
    /// page.  The ledger guarantees the conversion succeeds — a failure
    /// here is a page-accounting bug, not backpressure.  No-op on the
    /// dense layout.
    pub fn grow_to(&mut self, slot: usize, pos: usize) -> Result<()> {
        let Some(book) = &mut self.book else { return Ok(()) };
        let page_size = book.allocator.page_size();
        let needed = pos / page_size + 1;
        while book.tables[slot].len() < needed {
            anyhow::ensure!(
                book.reserved[slot] > 0,
                "slot {slot} needs page {} of {needed} with no reservation left \
                 (pos {pos}) — lazy-growth accounting bug",
                book.tables[slot].len(),
            );
            let Some(page) = book.allocator.try_grow_reserved() else {
                // only reachable above overcommit factor 1.0 (strictly
                // gated, a reservation always has a free page): the
                // caller must preempt or reclaim before retrying
                anyhow::bail!(
                    "slot {slot} growth ran dry under overcommit (pos {pos}) — \
                     preempt a victim or reclaim retained pages first"
                );
            };
            book.reserved[slot] -= 1;
            book.tables[slot].push(page);
            self.metrics.page_grows += 1;
        }
        // CoW invariant: the page receiving this tick's appended row is
        // past the shared prefix and private to this slot
        debug_assert!(
            needed - 1 >= book.shared[slot],
            "decode write would land in a shared prefix page"
        );
        debug_assert_eq!(book.allocator.refcount(book.tables[slot][needed - 1]), 1);
        Ok(())
    }

    /// Chunked-prefill growth: extend `slot`'s block table until it
    /// covers the first `rows` prompt rows, converting reservations like
    /// [`Self::grow_to`].  Unlike `grow_to` this carries no CoW write
    /// asserts — a chunk walk legitimately passes *through* the shared
    /// prefix (those pages are already in the table and the append-side
    /// block table routes their rows to the garbage page, so they are
    /// never written).  No-op on the dense layout or when the table
    /// already covers the rows.
    pub fn grow_prefill(&mut self, slot: usize, rows: usize) -> Result<()> {
        let Some(book) = &mut self.book else { return Ok(()) };
        let page_size = book.allocator.page_size();
        let needed = rows.max(1).div_ceil(page_size);
        while book.tables[slot].len() < needed {
            anyhow::ensure!(
                book.reserved[slot] > 0,
                "slot {slot} needs chunk page {} of {needed} with no reservation \
                 left (rows {rows}) — chunked-admission accounting bug",
                book.tables[slot].len(),
            );
            let Some(page) = book.allocator.try_grow_reserved() else {
                anyhow::bail!(
                    "slot {slot} chunk growth ran dry under overcommit \
                     (rows {rows}) — preempt a victim or reclaim first"
                );
            };
            book.reserved[slot] -= 1;
            book.tables[slot].push(page);
            self.metrics.page_grows += 1;
        }
        Ok(())
    }

    /// Reclaim one slot (every exit path runs through here): its unused
    /// growth reservations return to the pool, and its pages either
    /// **park** — clean retirement with the retained prefix pool on:
    /// the pages fully covered by the prompt enter the pool, the rest
    /// free — or release outright (`park: false`, the abort/cancel
    /// path, where prefill may never have written the pages).  No-op on
    /// the dense layout.
    pub fn release(&mut self, slot: usize, park: bool) {
        let Some(book) = &mut self.book else { return };
        let pages = std::mem::take(&mut book.tables[slot]);
        let prompt = std::mem::take(&mut book.prompts[slot]);
        let r = std::mem::take(&mut book.reserved[slot]);
        if r > 0 {
            book.allocator.unreserve(r);
        }
        book.shared[slot] = 0;
        book.prefilled[slot] = false;
        book.seq[slot] = 0;
        if pages.is_empty() {
            return;
        }
        if park && self.cfg.prefix_cache && self.cfg.share_prefixes {
            let page_size = book.allocator.page_size();
            book.pool.park(&prompt, pages, page_size, &mut book.allocator);
        } else {
            book.allocator.free(pages);
        }
    }

    // ---- two-tier hierarchy: overcommit swap + prefix demotion ----

    /// Whether the host tier holds any capacity (always `false` on the
    /// dense layout).
    pub fn host_tier_enabled(&self) -> bool {
        self.book.as_ref().is_some_and(|b| b.tier.enabled())
    }

    /// Host-tier movement counters (`None` on the dense layout).
    pub fn host_tier_stats(&self) -> Option<&HostTierStats> {
        self.book.as_ref().map(|b| b.tier.stats())
    }

    /// Bytes currently resident in the host tier (pinned + cached; 0 on
    /// the dense layout or with the tier disabled).
    pub fn host_tier_bytes(&self) -> usize {
        self.book
            .as_ref()
            .map_or(0, |b| b.tier.pinned_bytes() + b.tier.cached_bytes())
    }

    /// Drain the tier's pending real-byte operations (the real engine
    /// performs them at the tick's admission boundary, while demoted
    /// device pages are freed-but-unwritten; the simulator discards
    /// them).
    pub fn take_host_ops(&mut self) -> Vec<HostOp> {
        self.book.as_mut().map_or_else(Vec::new, |b| b.tier.take_ops())
    }

    /// Growth pages the KV writes in `growers` — `(slot, pos)` pairs,
    /// one per slot about to write at `pos` — would collectively need
    /// beyond what the free list can supply right now (0 = every
    /// growth is safe).  Batched so free pages are not double-counted
    /// across slots growing in the same step.  Only ever positive
    /// above overcommit factor 1.0.
    pub fn growth_deficit(&self, growers: &[(usize, usize)]) -> usize {
        let Some(book) = &self.book else { return 0 };
        let page_size = book.allocator.page_size();
        let needed: usize = growers
            .iter()
            .map(|&(slot, pos)| {
                (pos / page_size + 1).saturating_sub(book.tables[slot].len())
            })
            .sum();
        needed.saturating_sub(book.allocator.free_pages())
    }

    /// The deterministic victim policy: among `candidates` (slot
    /// indices), the **youngest-admitted** slot whose private pages
    /// (past its shared prefix) all carry refcount 1 — never a CoW
    /// donor with live sharers, whose pages could not actually leave
    /// the device.  `None` when no candidate is eligible.
    pub fn pick_victim(&self, candidates: &[usize]) -> Option<usize> {
        let book = self.book.as_ref()?;
        candidates
            .iter()
            .copied()
            .filter(|&s| !book.tables[s].is_empty())
            .filter(|&s| {
                book.tables[s][book.shared[s]..]
                    .iter()
                    .all(|&p| book.allocator.refcount(p) == 1)
            })
            .max_by_key(|&s| book.seq[s])
    }

    /// The youngest-admitted slot among `candidates` with a live page
    /// table, regardless of CoW sharing — the preemption order when
    /// even the host tier cannot take a swap and the victim must be
    /// requeued outright (releasing shared pages only drops refcounts,
    /// so a plain requeue is always legal).
    pub fn youngest_slot(&self, candidates: &[usize]) -> Option<usize> {
        let book = self.book.as_ref()?;
        candidates
            .iter()
            .copied()
            .filter(|&s| !book.tables[s].is_empty())
            .max_by_key(|&s| book.seq[s])
    }

    /// Preemptively swap `slot` out: pin its private page count to the
    /// host tier under `key` (the request id; `payload` carries the
    /// captured KV bytes on the real engine) and release the slot
    /// without parking.  Returns the pages pinned, or `None` — tier
    /// disabled, nothing private to move, or no pin headroom — with
    /// the slot untouched (the caller falls back to a plain requeue,
    /// which is always legal).
    pub fn swap_out(
        &mut self, slot: usize, key: u64, payload: Option<Vec<u8>>,
    ) -> Option<usize> {
        let book = self.book.as_mut()?;
        let private = book.tables[slot].len().saturating_sub(book.shared[slot]);
        if private == 0 || !book.tier.pin(key, private, payload) {
            return None;
        }
        self.release(slot, false);
        Some(private)
    }

    /// Device page ids private to `slot` (past its shared prefix) — the
    /// pages whose bytes the real engine captures before a swap-out.
    pub fn private_pages(&self, slot: usize) -> Vec<u32> {
        self.book
            .as_ref()
            .map_or_else(Vec::new, |b| b.tables[slot][b.shared[slot]..].to_vec())
    }

    /// Re-admit a previously swapped request: release its host pin,
    /// booking the host→device restore.  The pages themselves re-enter
    /// through the ordinary admission + seed-replay path (bit-identical
    /// regeneration); this is the accounting half.  `None` when `key`
    /// holds no pin.
    pub fn swap_in(&mut self, key: u64) -> Option<usize> {
        let book = self.book.as_mut()?;
        book.tier.unpin(key).map(|(pages, _payload)| pages)
    }

    /// Discard a swapped-out request's host copy without a restore (the
    /// request was cancelled or drained while preempted).
    pub fn drop_swapped(&mut self, key: u64) -> Option<usize> {
        self.book.as_mut()?.tier.drop_pin(key)
    }

    /// Attach the real KV bytes the engine captured for a demoted tier
    /// entry (the [`HostOp::Demote`] drain path).  Returns whether the
    /// entry still exists.
    pub fn attach_prefix_payload(&mut self, tokens: &[i32], payload: Vec<u8>) -> bool {
        self.book
            .as_mut()
            .is_some_and(|b| b.tier.attach_prefix_payload(tokens, payload))
    }

    /// Host bytes one KV page occupies in the tier (0 on the dense
    /// layout) — the unit every tier byte counter is denominated in.
    pub fn host_tier_page_bytes(&self) -> usize {
        self.book.as_ref().map_or(0, |b| b.tier.page_bytes())
    }

    /// Discard every host pin (engine `abort_all`).  Returns the pages
    /// dropped.
    pub fn drop_all_swapped(&mut self) -> usize {
        self.book.as_mut().map_or(0, |b| b.tier.drop_all_pins())
    }

    /// Spill retained prefix pages to cover a growth `deficit` (demoted
    /// to the host tier where capacity allows, evicted otherwise).
    /// Returns the device pages reclaimed — the cheap first resort
    /// before preemption.
    pub fn reclaim_for_growth(&mut self, deficit: usize) -> usize {
        let Some(book) = &mut self.book else { return 0 };
        if deficit == 0 {
            return 0;
        }
        let got = book.pool.spill_pages(deficit, &mut book.allocator, &mut book.tier);
        self.metrics.evictions += got as u64;
        got
    }

    /// Promote the host tier's best cached prefix for `prompt` back to
    /// the device (the engine calls this for the queue head before its
    /// admission phase, so `admissible_now`/`admit` see the promoted
    /// entry through the ordinary pool lookup — no gate arithmetic
    /// changes).  Gated like a warm preload: only when the tier's
    /// coverage beats the device pool's and the *unreserved* free pool
    /// can hold the pages.  Returns the pages promoted.
    pub fn promote_for(&mut self, prompt: &[i32]) -> usize {
        if !self.cfg.prefix_cache {
            return 0;
        }
        let Some(book) = &mut self.book else { return 0 };
        if !book.tier.enabled() {
            return 0;
        }
        let page_size = book.allocator.page_size();
        let Some(pages) = book.tier.peek_prefix(prompt) else { return 0 };
        let device = book.pool.lookup(prompt, page_size).map_or(0, |h| h.pages);
        if pages <= device || pages > book.allocator.unreserved_pages() {
            return 0;
        }
        let Some(fresh) = book.allocator.alloc(pages) else { return 0 };
        let (tokens, n) = book
            .tier
            .take_prefix(prompt, &fresh)
            .expect("peek_prefix hit cannot miss on take");
        debug_assert_eq!(n, pages);
        // park() dedups against whatever the device pool already holds,
        // freeing any duplicate pages it does not keep
        book.pool.park(&tokens, fresh, page_size, &mut book.allocator);
        pages
    }

    /// Export `prompt`'s retained prefix for the cluster store: an
    /// already-staged host copy is cloned back directly (no device
    /// traffic); otherwise the device pool's entry is *copied* into the
    /// tier (device→host, booked — the device entry stays, so local
    /// admissions are unaffected) and the device page ids are returned
    /// for the real engine's byte capture.  `None` when there is
    /// nothing to export or the tier cannot stage it — the tier is the
    /// only path off the device, there is no side channel.
    pub fn export_prefix(&mut self, prompt: &[i32]) -> Option<(PrefixKv, Vec<u32>)> {
        if !self.cfg.prefix_cache {
            return None;
        }
        let book = self.book.as_mut()?;
        if !book.tier.enabled() {
            return None;
        }
        let page_size = book.allocator.page_size();
        let device = book.pool.lookup(prompt, page_size).map_or(0, |h| h.pages);
        let staged = book.tier.peek_prefix(prompt).unwrap_or(0);
        if staged >= device && staged > 0 {
            let (tokens, pages, bytes) =
                book.tier.clone_prefix(prompt).expect("peeked");
            return Some((PrefixKv { tokens, pages, bytes }, Vec::new()));
        }
        if device == 0 {
            return None;
        }
        let hit = book.pool.lookup(prompt, page_size).expect("device > 0");
        let pages = book.pool.entry_pages(hit.idx)[..hit.pages].to_vec();
        let tokens = prompt[..hit.pages * page_size].to_vec();
        if !book.tier.ingest_prefix(&tokens, hit.pages, None, true) {
            return None;
        }
        Some((
            PrefixKv { tokens, pages: hit.pages, bytes: None },
            pages,
        ))
    }

    /// Cluster warm-start through the hierarchy: ingest the payload (or
    /// a logical placeholder) into the host tier's cached class —
    /// host-side, no device transfer — then promote it to the device on
    /// the spot through [`Self::promote_for`]'s gated path.  With the
    /// tier disabled this falls back to the PR-8 single-tier
    /// [`Self::preload_prefix`] bit for bit.  Returns the pages that
    /// reached the device (pages left staged host-side count 0, like a
    /// declined preload — they can still promote on demand later).
    pub fn warm_prefix_host(&mut self, prompt: &[i32], payload: Option<&PrefixKv>) -> usize {
        if !self.cfg.prefix_cache {
            return 0;
        }
        if self.book.is_none() {
            return 0;
        }
        if !self.host_tier_enabled() {
            return self.preload_prefix(prompt);
        }
        let book = self.book.as_mut().expect("checked above");
        let page_size = book.allocator.page_size();
        let full = prompt.len() / page_size;
        if full == 0 {
            return 0;
        }
        let (kv_pages, bytes) = match payload {
            Some(kv) if kv.pages <= full && kv.pages > 0 => {
                (kv.pages, kv.bytes.clone())
            }
            _ => (full, None),
        };
        book.tier
            .ingest_prefix(&prompt[..kv_pages * page_size], kv_pages, bytes, false);
        self.promote_for(prompt)
    }

    /// The `(B, pages_per_slot)` i32 block table for the current slot
    /// assignments; unallocated tail entries point at the reserved
    /// garbage page.  With `for_append`, each slot's leading shared
    /// prefix entries are ALSO routed to the garbage page: `page_append`
    /// must never rewrite a donor's (or the retained pool's) live pages
    /// — the sharer's prefill rows for those positions are
    /// bit-identical anyway, and skipping the write is what makes
    /// prefix sharing copy-free — while the decode table keeps the real
    /// ids so gathers see the shared prefix.
    ///
    /// Panics on the dense layout (the engine never builds a block
    /// table there).
    pub fn block_table(&self, for_append: bool) -> Result<Tensor> {
        let book = self.book.as_ref().expect("block table on the dense layout");
        let pps = book.pages_per_slot;
        let mut bt = vec![RESERVED_PAGE as i32; self.width * pps];
        for (slot, pages) in book.tables.iter().enumerate() {
            let skip = if for_append { book.shared[slot] } else { 0 };
            for (j, &p) in pages.iter().enumerate().skip(skip) {
                bt[slot * pps + j] = p as i32;
            }
        }
        Tensor::from_i32(&[self.width, pps], bt)
    }

    /// Full cross-structure consistency check (property tests run it
    /// after every operation): allocator partition + ledger, prefix
    /// index vs allocator, per-slot reservation sum vs the ledger,
    /// every table page referenced.  Panics on the first violation.
    /// No-op on the dense layout.
    pub fn audit(&self) {
        let Some(book) = &self.book else { return };
        book.allocator.audit();
        book.pool.audit(&book.allocator, book.allocator.page_size());
        book.tier.audit();
        let mut reserved = 0usize;
        for (slot, table) in book.tables.iter().enumerate() {
            for &p in table {
                assert!(
                    p != RESERVED_PAGE && book.allocator.refcount(p) >= 1,
                    "slot {slot} references unallocated page {p}"
                );
            }
            assert!(
                book.shared[slot] <= table.len(),
                "slot {slot} shared count exceeds its table"
            );
            reserved += book.reserved[slot];
        }
        for adm in &book.pending {
            for &p in &adm.table {
                assert!(book.allocator.refcount(p) >= 1, "pending admission page {p} free");
            }
            reserved += adm.reserve;
        }
        assert_eq!(
            reserved,
            book.allocator.reserved_pages(),
            "per-slot reservations drifted from the ledger"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PAGE: usize = 16;
    const MAX: usize = 160;

    fn mgr(num_pages: usize, cfg: KvCacheConfig) -> KvCacheManager {
        KvCacheManager::paged(4, MAX, num_pages, PAGE, MAX / PAGE, cfg)
    }

    fn plan(
        prompt: &[i32], max_new: usize, lazy: bool, donors: &[(Vec<i32>, Vec<u32>)],
    ) -> AdmitPlan {
        let cfg = KvCacheConfig { lazy_growth: lazy, ..Default::default() };
        mgr(41, cfg).plan(prompt, max_new, donors)
    }

    #[test]
    fn pages_needed_covers_lifetime_and_clamps() {
        let m = mgr(41, KvCacheConfig::default());
        assert_eq!(m.pages_needed(6, 8), 1, "14 rows fit one page");
        assert_eq!(m.pages_needed(30, 40), 5, "70 rows need 5 pages");
        assert_eq!(m.pages_needed(100, 500), 10, "clamped to max_len");
        assert_eq!(m.pages_needed(0, 4), 1, "empty prompt still holds a row");
    }

    #[test]
    fn oversized_requests_are_never_admissible() {
        // regression (PR-4 satellite): a pool smaller than one slot's
        // span must reject requests whose worst case exceeds it at
        // submit — queued, they would head-block the FIFO forever
        let m = mgr(3, KvCacheConfig::default()); // 2 usable
        assert!(m.ever_admissible(6, 8), "1-page request fits");
        assert!(m.ever_admissible(16, 16), "2-page request fits exactly");
        assert!(!m.ever_admissible(30, 40), "5-page worst case never fits");
        // the shipped geometry (40 usable, 10-page span) can admit any
        // single request — the guard exists for smaller provisioning
        let shipped = mgr(41, KvCacheConfig::default());
        assert!(shipped.ever_admissible(100, 10_000), "clamped to the span");
    }

    // ---- admission planner: lazy growth + copy-on-write sharing ----

    #[test]
    fn eager_plan_is_full_worst_case_up_front() {
        let p = plan(&[1; 20], 40, false, &[]);
        assert_eq!(p.fresh, 4, "ceil(60/16) pages allocated at admission");
        assert_eq!(p.reserve, 0, "eager reserves nothing");
        assert!(p.shared.is_empty());
        assert!(!p.cow_copy);
    }

    #[test]
    fn lazy_plan_grants_prompt_pages_plus_one_and_reserves_the_rest() {
        // prompt 20 → 2 pages; +1 decode page; worst case ceil(60/16)=4
        let p = plan(&[1; 20], 40, true, &[]);
        assert_eq!(p.fresh, 3);
        assert_eq!(p.reserve, 1);
        // total commitment always equals the worst case
        assert_eq!(p.fresh + p.reserve, plan(&[1; 20], 40, false, &[]).fresh);
    }

    #[test]
    fn lazy_plan_caps_the_decode_page_at_the_worst_case() {
        // prompt 10, budget 3: 13 rows fit the single prompt page — no
        // extra decode page, nothing to reserve
        let p = plan(&[1; 10], 3, true, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 0));
        // empty prompt still occupies one row
        let p = plan(&[], 4, true, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 0));
    }

    #[test]
    fn sharing_takes_only_full_common_prefix_pages() {
        let donor_prompt: Vec<i32> = (0..30).collect();
        let donor_table: Vec<u32> = vec![7, 8, 9]; // 2 prompt pages + decode page
        let donors = vec![(donor_prompt.clone(), donor_table)];
        // identical 30-token prompt: common=30 → 1 full page shared (the
        // page holding rows 16..29 is the boundary page — it will take
        // this slot's first decode writes, so it is copied, not shared
        let p = plan(&donor_prompt, 40, true, &donors);
        assert_eq!(p.shared, vec![7], "one full prefix page shared");
        assert!(p.cow_copy, "boundary page with matching rows was privatized");
        // commitment shrinks by exactly the shared pages
        let solo = plan(&donor_prompt, 40, true, &[]);
        assert_eq!(p.fresh + p.reserve + 1, solo.fresh + solo.reserve);
        // a 32-token twin shares both full pages and cow-copies nothing
        let two_pages: Vec<i32> = (0..32).collect();
        let donors = vec![(two_pages.clone(), vec![4, 5, 6])];
        let p = plan(&two_pages, 8, true, &donors);
        assert_eq!(p.shared, vec![4, 5]);
        assert!(!p.cow_copy, "prefix ends exactly on a page boundary");
    }

    #[test]
    fn sharing_never_reaches_a_page_either_side_could_write() {
        // donor prompt 20 (partial page 1), candidate identical: only
        // page 0 is fully inside both prompts
        let donor: Vec<i32> = (100..120).collect();
        let donors = vec![(donor.clone(), vec![3, 4, 5])];
        let p = plan(&donor, 16, true, &donors);
        assert_eq!(p.shared, vec![3], "partial pages are never shared");
        // unrelated prompt shares nothing
        let q = plan(&[9; 20], 16, true, &donors);
        assert!(q.shared.is_empty());
        assert!(!q.cow_copy);
        // sub-page common prefix: nothing shareable, and with zero
        // shared pages there is nothing to copy either — an ordinary
        // private admission, not a CoW event (metric stays meaningful)
        let mut near = donor.clone();
        near[10] = -1;
        let r = plan(&near, 16, true, &donors);
        assert!(r.shared.is_empty());
        assert!(!r.cow_copy);
    }

    #[test]
    fn best_donor_wins_and_same_wave_donors_are_usable() {
        let long: Vec<i32> = (0..32).collect();
        let donors = vec![
            (long[..16].to_vec(), vec![2, 3]), // 1 shareable page
            (long.clone(), vec![4, 5, 6]),     // 2 shareable pages
        ];
        let p = plan(&long, 8, true, &donors);
        assert_eq!(p.shared, vec![4, 5], "longest common prefix wins");
    }

    // ---- retained prefix pool: park / hit / evict lifecycle ----

    /// Admit + install one request into `slot`, asserting the gate
    /// opened.
    fn admit_install(m: &mut KvCacheManager, slot: usize, prompt: &[i32], max_new: usize) {
        assert!(m.admit(prompt, max_new), "admission starved unexpectedly");
        m.install(slot);
        m.audit();
    }

    #[test]
    fn full_prefix_hit_admits_with_zero_fresh_prompt_pages() {
        // THE satellite unit test: a prompt that fully hits the
        // retained pool allocates only its decode page — zero fresh
        // prompt pages.
        let mut m = mgr(41, KvCacheConfig::default());
        let prompt: Vec<i32> = (0..32).collect(); // exactly 2 pages
        admit_install(&mut m, 0, &prompt, 8);
        m.release(0, true); // retirement parks both prompt pages
        assert_eq!(m.retained_pages(), Some(2));
        let free_before = m.book.as_ref().unwrap().allocator.free_pages();
        admit_install(&mut m, 1, &prompt, 8);
        let free_after = m.book.as_ref().unwrap().allocator.free_pages();
        assert_eq!(
            free_before - free_after,
            1,
            "only the decode page was allocated fresh"
        );
        assert_eq!(m.metrics().prefix_hits, 1);
        assert_eq!(
            m.metrics().prefix_hit_tokens as usize,
            prompt.len(),
            "the whole prompt was served from the retained pool"
        );
        assert_eq!(m.retained_pages(), Some(0), "hit pages are outstanding again");
        // retirement of the sharer re-parks the same pages, no growth
        m.release(1, true);
        assert_eq!(m.retained_pages(), Some(2));
        m.audit();
    }

    #[test]
    fn pool_off_restores_pr4_free_at_retirement() {
        let cfg = KvCacheConfig { prefix_cache: false, ..Default::default() };
        let mut m = mgr(41, cfg);
        let prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &prompt, 8);
        m.release(0, true);
        assert_eq!(m.retained_pages(), Some(0), "nothing parks with the pool off");
        admit_install(&mut m, 1, &prompt, 8);
        assert_eq!(m.metrics().prefix_hits, 0);
        assert_eq!(m.metrics().shared_pages, 0, "no donor, nothing shared");
    }

    #[test]
    fn abort_release_never_parks() {
        let mut m = mgr(41, KvCacheConfig::default());
        let prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &prompt, 8);
        m.release(0, false); // cancel/abort: pages may be unwritten
        assert_eq!(m.retained_pages(), Some(0));
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        m.audit();
    }

    #[test]
    fn starved_admission_evicts_lru_but_never_live_pages() {
        // pool: 8 usable pages, span 4 pages (max_len 64, page 16)
        let mut m = KvCacheManager::paged(4, 64, 9, PAGE, 4, KvCacheConfig::default());
        // two retired prompts park 2 pages each (cold first, hot second)
        let cold: Vec<i32> = (0..32).collect();
        let hot: Vec<i32> = (100..132).collect();
        admit_install(&mut m, 0, &cold, 4);
        m.release(0, true);
        admit_install(&mut m, 0, &hot, 4);
        m.release(0, true);
        assert_eq!(m.retained_pages(), Some(4));
        // a hot-prefix admission re-shares 2 pages (touching the entry)
        admit_install(&mut m, 1, &hot, 4);
        assert_eq!(m.metrics().prefix_hits, 1);
        // unrelated demand (4 pages) vs 3 free: eviction must reclaim
        // from the LRU cold entry; the hot entry's pages are live
        // (slot 1 references them) and must survive untouched
        let stranger: Vec<i32> = (900..948).collect(); // 3 pages + budget
        assert!(m.admit(&stranger, 16), "eviction must unblock the admission");
        m.install(2);
        m.audit();
        assert!(m.metrics().evictions >= 1, "the cold entry was reclaimed");
        // the hot pages are still shared by slot 1 (refcounted, unharmed)
        assert_eq!(m.metrics().shared_pages, 2);
        // full reclamation after everything retires
        m.release(1, true);
        m.release(2, true);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        assert_eq!(m.reservations(), Some(0));
        m.audit();
    }

    #[test]
    fn admissible_now_counts_evictable_head_for_liveness() {
        // every usable page parked, nothing in flight: the head of the
        // queue MUST read as admissible (via eviction) or the engine
        // would idle with work queued
        let mut m = KvCacheManager::paged(2, 64, 9, PAGE, 4, KvCacheConfig::default());
        for (slot, base) in [(0usize, 0i32), (1, 500)] {
            let p: Vec<i32> = (base..base + 48).collect(); // 3 pages
            admit_install(&mut m, slot, &p, 16);
        }
        m.release(0, true);
        m.release(1, true);
        assert_eq!(m.retained_pages(), Some(6), "prompt pages parked");
        let stranger: Vec<i32> = (900..948).collect();
        let queued = [(stranger.as_slice(), 16usize)];
        let n = m.admissible_now(queued.iter().copied(), 1, 2);
        assert_eq!(n, 1, "head admissibility must see through the parked pool");
        // and the real gate agrees (sim/commit head exactness)
        assert!(m.admit(&stranger, 16));
        m.install(0);
        m.audit();
    }

    // ---- chunked-prefill admission (chunk_rows) ----

    #[test]
    fn chunked_plan_grants_first_chunk_and_reserves_the_rest() {
        // prompt 40 (3 pages), chunk 16 (1 page), budget 40: worst =
        // ceil(80/16) = 5 pages; admission grants only the chunk page
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let p = mgr(41, cfg).plan(&[1; 40], 40, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 4));
        // total commitment still equals the worst case
        assert_eq!(p.fresh + p.reserve, 5);
        // a prompt shorter than the chunk admits like one chunk
        let p = mgr(41, cfg).plan(&[1; 10], 3, &[]);
        assert_eq!((p.fresh, p.reserve), (1, 0));
    }

    #[test]
    fn chunked_plan_keeps_shared_prefix_pages_in_the_table() {
        // the shared prefix (2 pages) exceeds the first chunk (1 page):
        // the table still holds every shared entry — sharing is
        // unchanged by chunking, only fresh-page timing moves
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let donor: Vec<i32> = (0..32).collect();
        let donors = vec![(donor.clone(), vec![4, 5, 6])];
        let p = mgr(41, cfg).plan(&donor, 40, &donors);
        assert_eq!(p.shared, vec![4, 5], "chunking must not shrink sharing");
        assert_eq!(p.fresh, 0, "shared pages already cover the first chunk");
        // commitment unchanged vs the monolithic plan
        let mono = mgr(41, KvCacheConfig::default()).plan(&donor, 40, &donors);
        assert_eq!(
            p.shared.len() + p.fresh + p.reserve,
            mono.shared.len() + mono.fresh + mono.reserve
        );
    }

    #[test]
    fn grow_prefill_converts_reservations_chunk_by_chunk() {
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let mut m = mgr(41, cfg);
        let prompt: Vec<i32> = (0..40).collect(); // 3 prompt pages
        admit_install(&mut m, 0, &prompt, 40);
        assert_eq!(m.reservations(), Some(4));
        // chunk walk: 16 rows covered at admission, then 32, then 40
        m.grow_prefill(0, 16).unwrap();
        assert_eq!(m.reservations(), Some(4), "chunk 1 already covered");
        m.grow_prefill(0, 32).unwrap();
        assert_eq!(m.reservations(), Some(3));
        m.grow_prefill(0, 40).unwrap();
        assert_eq!(m.reservations(), Some(2), "prompt fully paged");
        m.audit();
        // decode growth continues from the same ledger
        m.grow_to(0, 48).unwrap();
        assert_eq!(m.reservations(), Some(1));
        // mid-prefill release (the cancel path) reclaims pages AND the
        // remaining reservations
        m.release(0, false);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        assert_eq!(m.reservations(), Some(0));
        m.audit();
    }

    #[test]
    fn chunked_admissible_now_matches_the_chunked_gate() {
        // head-exactness must hold under chunked admission arithmetic
        // too: the sim and the gate share plan(), so a pool with room
        // for one first-chunk grant admits exactly one
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let mut m = KvCacheManager::paged(2, 64, 5, PAGE, 4, cfg); // 4 usable
        let big: Vec<i32> = (0..48).collect(); // worst 4 pages
        let queued = [(big.as_slice(), 16usize)];
        let n = m.admissible_now(queued.iter().copied(), 1, 2);
        assert_eq!(n, 1);
        assert!(m.admit(&big, 16), "sim and gate agree");
        m.install(0);
        m.audit();
    }

    #[test]
    fn chunked_sharing_waits_for_donor_prefill() {
        // regression (PR-7): a mid-chunk slot's pages hold no KV — it
        // must not donate CoW prefixes until its prefill commits, or a
        // sharer can read garbage / orphan the page under requeue
        let cfg = KvCacheConfig { chunk_rows: Some(16), ..Default::default() };
        let mut m = mgr(41, cfg);
        let prompt: Vec<i32> = (0..32).collect(); // 2 full pages
        admit_install(&mut m, 0, &prompt, 8);
        // donor admitted but unprefilled: an identical prompt shares 0
        admit_install(&mut m, 1, &prompt, 8);
        assert_eq!(m.metrics().shared_pages, 0, "unwritten donor must not share");
        m.release(1, false);
        // prefill commits → the same admission now shares both pages
        m.mark_prefilled(0);
        admit_install(&mut m, 1, &prompt, 8);
        assert_eq!(m.metrics().shared_pages, 2, "written donor shares normally");
        // same-wave pending admissions never donate under chunking
        assert!(m.admit(&prompt, 8), "pending admission");
        assert!(m.admit(&prompt, 8), "second of the wave");
        assert_eq!(
            m.metrics().shared_pages,
            2 + 2 + 2,
            "both wave members shared only from the prefilled live donor"
        );
        m.install(2);
        m.install(3);
        m.audit();
        // the monolithic planner ignores the flag entirely (PR-6 parity)
        let mut mono = mgr(41, KvCacheConfig::default());
        admit_install(&mut mono, 0, &prompt, 8);
        admit_install(&mut mono, 1, &prompt, 8);
        assert_eq!(mono.metrics().shared_pages, 2, "monolithic shares unprefilled");
    }

    #[test]
    fn conservation_across_a_mixed_wave() {
        let mut m = mgr(21, KvCacheConfig::default()); // 20 usable
        let shared_prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &shared_prompt, 40);
        admit_install(&mut m, 1, &shared_prompt, 8); // shares 2 pages
        admit_install(&mut m, 2, &[7; 10], 4);
        assert!(m.metrics().shared_pages >= 2);
        // grow slot 0 across a boundary
        m.grow_to(0, 48).unwrap();
        assert!(m.metrics().page_grows >= 1);
        m.audit();
        // retire in donor-first order; pages park, conservation holds
        m.release(0, true);
        m.release(1, true);
        m.release(2, true);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable, "free + retained covers the pool");
        assert_eq!(m.reservations(), Some(0));
        m.audit();
    }

    #[test]
    fn preload_prefix_parks_pages_and_serves_the_next_admission() {
        let mut m = mgr(41, KvCacheConfig::default());
        let prompt: Vec<i32> = (0..40).collect(); // 2 full pages + remainder
        assert_eq!(m.preload_prefix(&prompt), 2, "both full pages parked");
        assert_eq!(m.retained_pages(), Some(2));
        m.audit();
        // idempotent: the pool already covers this prefix
        assert_eq!(m.preload_prefix(&prompt), 0);
        // the next admission of the same prompt shares the warmed pages
        admit_install(&mut m, 0, &prompt, 8);
        assert_eq!(m.metrics().prefix_hits, 1, "admission hit the warmed entry");
        assert!(m.metrics().prefix_hit_tokens >= 32);
        m.release(0, true);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable, "conservation holds after retirement");
        m.audit();
    }

    #[test]
    fn preload_prefix_respects_headroom_retention_and_layout() {
        // sub-page prompts install nothing
        let mut m = mgr(41, KvCacheConfig::default());
        assert_eq!(m.preload_prefix(&[1; 10]), 0, "no full page to park");
        // never competes with growth reservations: lazy slot holds the
        // pool's headroom hostage, the preload declines instead
        let mut small = mgr(5, KvCacheConfig::default()); // 4 usable
        admit_install(&mut small, 0, &[7; 20], 30); // 3 fresh + 1 reserved
        assert_eq!(small.reservations(), Some(1));
        let long: Vec<i32> = (100..148).collect(); // wants 3 pages
        assert_eq!(small.preload_prefix(&long), 0, "unreserved headroom too small");
        small.audit();
        // retention off / dense layout: structurally a no-op
        let cfg = KvCacheConfig { prefix_cache: false, ..Default::default() };
        assert_eq!(mgr(41, cfg).preload_prefix(&[1; 40]), 0);
        let mut dense = KvCacheManager::dense(4, MAX, KvCacheConfig::default());
        assert_eq!(dense.preload_prefix(&[1; 40]), 0);
    }

    // ---- two-tier hierarchy: overcommit, swap, demote/promote ----

    /// Page-16 geometry with an overcommit factor and a host tier of
    /// `cap_pages` 64-byte pages.
    fn tier_cfg(factor: f64, cap_pages: usize) -> KvCacheConfig {
        KvCacheConfig {
            overcommit_factor: factor,
            host_tier: host_tier::HostTierConfig {
                capacity_bytes: cap_pages * 64,
                page_bytes: 64,
            },
            ..Default::default()
        }
    }

    #[test]
    fn baseline_config_keeps_every_tier_path_inert() {
        // factor 1.0 + zero-capacity tier: the PR-8 single-tier manager
        let mut m = KvCacheManager::paged(4, 64, 9, PAGE, 4, KvCacheConfig::default());
        assert!(!m.host_tier_enabled());
        assert_eq!(m.host_tier_bytes(), 0);
        assert_eq!(m.promote_for(&[1; 32]), 0);
        assert!(m.export_prefix(&[1; 32]).is_none());
        assert_eq!(m.growth_deficit(&[(0, 16)]), 0);
        let prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &prompt, 16);
        assert!(m.swap_out(0, 7, None).is_none(), "disabled tier never pins");
        assert_eq!(m.drop_all_swapped(), 0);
        m.release(0, true);
        m.audit();
    }

    #[test]
    fn overcommit_admits_past_free_then_swap_unblocks_growth() {
        let mut m = KvCacheManager::paged(4, 64, 9, PAGE, 4, tier_cfg(1.5, 8));
        let mut strict = KvCacheManager::paged(4, 64, 9, PAGE, 4, KvCacheConfig::default());
        let a: Vec<i32> = (0..32).collect();
        let b: Vec<i32> = (100..132).collect();
        let d: Vec<i32> = (200..208).collect();
        for m in [&mut m, &mut strict] {
            admit_install(m, 0, &a, 32); // fresh 3 + reserve 1
            admit_install(m, 1, &b, 32); // fresh 3 + reserve 1 → free 2
        }
        // D (1 fresh page, nothing reserved): the strict gate has zero
        // unreserved headroom; the overcommit gate admits against the
        // inflated watermark — and the sim mirrors both, head-exactly
        let queued = [(d.as_slice(), 8usize)];
        assert_eq!(strict.admissible_now(queued.iter().copied(), 1, 2), 0);
        assert!(!strict.admit(&d, 8), "strict gate starves");
        assert_eq!(m.admissible_now(queued.iter().copied(), 1, 2), 1);
        admit_install(&mut m, 2, &d, 8);
        assert_eq!(m.reservations(), Some(2), "ledger now exceeds free");
        m.audit();
        // slot 0 grows into the last free page; slot 1's growth then
        // runs dry — the victim policy swaps the youngest slot out and
        // the freed page un-dries the ledger
        m.grow_to(0, 48).unwrap();
        assert_eq!(m.growth_deficit(&[(1, 48)]), 1, "free list is dry");
        assert_eq!(m.reclaim_for_growth(1), 0, "nothing retained to spill");
        assert_eq!(m.pick_victim(&[2]), Some(2), "youngest private slot");
        assert_eq!(m.swap_out(2, 99, None), Some(1), "one private page pinned");
        assert_eq!(m.host_tier_bytes(), 64);
        assert_eq!(m.growth_deficit(&[(1, 48)]), 0);
        m.grow_to(1, 48).unwrap();
        m.audit();
        // the preempted request is cancelled while swapped: its host
        // copy drops without a restore transfer
        assert_eq!(m.drop_swapped(99), Some(1));
        assert_eq!(m.host_tier_stats().unwrap().bytes_to_device, 0);
        m.release(0, false);
        m.release(1, false);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable, "full conservation after the drain");
        assert_eq!(m.reservations(), Some(0));
        m.audit();
    }

    #[test]
    fn pressure_demotes_retained_prefixes_and_promote_restores_them() {
        let mut m = KvCacheManager::paged(4, 64, 8, PAGE, 4, tier_cfg(1.0, 8));
        let hot: Vec<i32> = (0..32).collect();
        let cold: Vec<i32> = (100..132).collect();
        for p in [&hot, &cold] {
            admit_install(&mut m, 0, p, 16);
            m.release(0, true);
        }
        assert_eq!(m.retained_pages(), Some(4));
        // a 4-page admission against 3 free: the LRU (hot) entry spills
        // — wholesale, to the host tier — instead of being discarded
        let stranger: Vec<i32> = (900..948).collect();
        admit_install(&mut m, 0, &stranger, 16);
        let tier = m.host_tier_stats().unwrap();
        assert_eq!(tier.demoted_pages, 2, "whole hot entry demoted, not lost");
        assert_eq!(m.host_tier_bytes(), 2 * 64);
        assert_eq!(m.metrics().evictions, 2, "device-side reclaim still counted");
        m.release(0, false);
        // the hot prefix comes back through the gated promotion path
        assert_eq!(m.promote_for(&hot), 2);
        assert_eq!(m.host_tier_bytes(), 0);
        assert_eq!(m.host_tier_stats().unwrap().bytes_to_device, 2 * 64);
        admit_install(&mut m, 0, &hot, 16);
        assert_eq!(m.metrics().prefix_hits, 1, "admission hit the promoted entry");
        m.release(0, true);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        m.audit();
    }

    #[test]
    fn victim_policy_skips_cow_donors_with_live_sharers() {
        let mut m = KvCacheManager::paged(4, 64, 9, PAGE, 4, tier_cfg(1.5, 8));
        let prompt: Vec<i32> = (0..32).collect();
        admit_install(&mut m, 0, &prompt, 16);
        m.mark_prefilled(0);
        admit_install(&mut m, 1, &prompt, 16); // shares slot 0's prefix
        assert_eq!(m.metrics().shared_pages, 2);
        // slot 0's prompt pages carry slot 1's references: not a victim
        assert_eq!(m.pick_victim(&[0]), None, "donor with live sharers is safe");
        assert_eq!(m.pick_victim(&[0, 1]), Some(1), "the sharer itself is fair game");
        // the sharer's swap moves only its private (non-borrowed) page
        assert_eq!(m.swap_out(1, 5, None), Some(1));
        // with the sharer gone the donor's pages are private again
        assert_eq!(m.pick_victim(&[0]), Some(0));
        m.drop_swapped(5);
        m.release(0, false);
        let (reclaimable, usable) = m.page_budget().unwrap();
        assert_eq!(reclaimable, usable);
        m.audit();
    }

    #[test]
    fn warm_and_export_route_through_the_tier() {
        let mut m = KvCacheManager::paged(2, 64, 9, PAGE, 4, tier_cfg(1.0, 8));
        let prompt: Vec<i32> = (0..40).collect(); // 2 full pages + remainder
        // warm-start: wire → host tier → device, promotion booked
        assert_eq!(m.warm_prefix_host(&prompt, None), 2);
        assert_eq!(m.retained_pages(), Some(2), "pages reached the device pool");
        let tier = m.host_tier_stats().unwrap();
        assert_eq!(tier.ingested_pages, 2, "wire arrival booked as ingest");
        assert_eq!(tier.bytes_to_device, 2 * 64, "promotion booked the upload");
        assert_eq!(tier.bytes_to_host, 0, "nothing ever moved off the device");
        // export stages a device→host copy (the device entry survives)
        let (kv, device_pages) = m.export_prefix(&prompt).expect("retained entry");
        assert_eq!((kv.pages, kv.bytes.is_none()), (2, true));
        assert_eq!(device_pages.len(), 2, "page ids for the engine's capture");
        assert_eq!(m.retained_pages(), Some(2), "export copies, never steals");
        assert_eq!(m.host_tier_stats().unwrap().bytes_to_host, 2 * 64);
        // a second export re-serves the staged host copy: no new bytes
        let (kv2, pages2) = m.export_prefix(&prompt).expect("staged copy");
        assert_eq!(kv2.pages, 2);
        assert!(pages2.is_empty(), "no device capture needed");
        assert_eq!(m.host_tier_stats().unwrap().bytes_to_host, 2 * 64);
        // the warmed entry serves admissions exactly like a preload
        admit_install(&mut m, 0, &prompt, 8);
        assert_eq!(m.metrics().prefix_hits, 1);
        m.release(0, true);
        m.audit();
        // with the tier disabled, warm falls back to PR-8 preload and
        // export has no path off the device
        let mut off = mgr(41, KvCacheConfig::default());
        assert_eq!(off.warm_prefix_host(&prompt, None), 2);
        assert_eq!(off.retained_pages(), Some(2));
        assert_eq!(off.host_tier_bytes(), 0);
        assert!(off.export_prefix(&prompt).is_none());
    }
}
