//! Workload trace generation for serving experiments.
//!
//! The paper benchmarks fixed batches; a serving deployment sees arrival
//! *processes*.  This module generates reproducible request traces —
//! Poisson, bursty (Markov-modulated), and closed-loop — used by the
//! `serve` example and the scheduler ablations.

use crate::rng::Rng;

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct TraceItem {
    /// arrival time in seconds from trace start
    pub at: f64,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Generation budget in tokens.
    pub max_new: usize,
}

/// Arrival process shape.
#[derive(Clone, Copy, Debug)]
pub enum Arrival {
    /// Poisson with constant rate (req/s).
    Poisson { rate: f64 },
    /// Two-state Markov-modulated Poisson: calm/burst rates and mean
    /// state dwell time.
    Bursty { calm_rate: f64, burst_rate: f64, dwell_s: f64 },
}

/// Trace configuration.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    /// Number of requests.
    pub n: usize,
    /// Arrival process.
    pub arrival: Arrival,
    /// Minimum prompt length.
    pub prompt_min: usize,
    /// Maximum prompt length (inclusive).
    pub prompt_max: usize,
    /// Minimum generation budget.
    pub max_new_min: usize,
    /// Maximum generation budget (inclusive).
    pub max_new_max: usize,
    /// Trace seed.
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n: 64,
            arrival: Arrival::Poisson { rate: 8.0 },
            prompt_min: 4,
            prompt_max: 28,
            max_new_min: 4,
            max_new_max: 16,
            seed: 0,
        }
    }
}

/// Generate a reproducible trace.
pub fn generate(cfg: &TraceConfig) -> Vec<TraceItem> {
    let mut rng = Rng::new(cfg.seed ^ 0x7EACE);
    let mut t = 0.0f64;
    let mut bursting = false;
    let mut next_switch = 0.0f64;
    (0..cfg.n)
        .map(|_| {
            let rate = match cfg.arrival {
                Arrival::Poisson { rate } => rate,
                Arrival::Bursty { calm_rate, burst_rate, dwell_s } => {
                    if t >= next_switch {
                        bursting = !bursting;
                        next_switch = t + rng.exponential(1.0 / dwell_s.max(1e-9));
                    }
                    if bursting {
                        burst_rate
                    } else {
                        calm_rate
                    }
                }
            };
            // degenerate rates (0, negative, NaN from a bad division)
            // clamp to a tiny-but-positive rate: arrivals stay finite
            // and monotone instead of stacking at +inf
            t += rng.exponential(if rate > 1e-9 { rate } else { 1e-9 });
            // inverted bounds (max < min) collapse to the min instead
            // of underflowing usize
            let span = cfg.prompt_max.saturating_sub(cfg.prompt_min) as u64 + 1;
            let nspan = cfg.max_new_max.saturating_sub(cfg.max_new_min) as u64 + 1;
            TraceItem {
                at: t,
                prompt_len: cfg.prompt_min + rng.below(span) as usize,
                max_new: cfg.max_new_min + rng.below(nspan) as usize,
            }
        })
        .collect()
}

/// Offered load in tokens/s over the trace span (sizing aid).
///
/// An empty trace is a zero summary, not a panic — callers summarise
/// whatever slice of a trace they were handed, including none of it.
pub fn offered_load(trace: &[TraceItem]) -> f64 {
    let Some(last) = trace.last() else {
        return 0.0;
    };
    let tokens: usize = trace.iter().map(|r| r.prompt_len + r.max_new).sum();
    tokens as f64 / last.at.max(1e-9)
}

/// Offered-load summary of a trace ([`load_summary`]): the mean rates
/// plus the peak demand a sliding window sees — the number that decides
/// whether a burst overruns the front-end's shed watermark.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LoadSummary {
    /// Trace span in seconds (first to last arrival).
    pub span_s: f64,
    /// Mean arrival rate over the span, requests/s.
    pub requests_per_s: f64,
    /// Mean offered load over the span, tokens/s.
    pub tokens_per_s: f64,
    /// Mean *prompt*-token rate over the span, tokens/s — the demand a
    /// chunked-prefill budget must absorb per second of trace time
    /// (decode tokens excluded: they pace themselves one per step).
    pub prompt_tokens_per_s: f64,
    /// Peak offered load over any `window_s` window, tokens/s.
    pub peak_tokens_per_s: f64,
}

/// Summarise a trace's offered load, with the peak taken over a sliding
/// window of `window_s` seconds.  Empty traces and degenerate windows
/// yield a zero summary, not a panic.
pub fn load_summary(trace: &[TraceItem], window_s: f64) -> LoadSummary {
    let (Some(first), Some(last)) = (trace.first(), trace.last()) else {
        return LoadSummary::default();
    };
    let span = (last.at - first.at).max(1e-9);
    let w = if window_s > 1e-9 { window_s } else { 1e-9 };
    let tokens: usize = trace.iter().map(|r| r.prompt_len + r.max_new).sum();
    let prompt_tokens: usize = trace.iter().map(|r| r.prompt_len).sum();
    let mut peak = 0.0f64;
    let mut start = 0usize;
    let mut win_tokens = 0usize;
    for item in trace {
        win_tokens += item.prompt_len + item.max_new;
        while trace[start].at < item.at - w {
            win_tokens -= trace[start].prompt_len + trace[start].max_new;
            start += 1;
        }
        peak = peak.max(win_tokens as f64 / w);
    }
    LoadSummary {
        span_s: span,
        requests_per_s: trace.len() as f64 / span,
        tokens_per_s: tokens as f64 / span,
        prompt_tokens_per_s: prompt_tokens as f64 / span,
        peak_tokens_per_s: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let cfg = TraceConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.prompt_len, y.prompt_len);
        }
    }

    #[test]
    fn arrivals_monotone_and_bounded() {
        let cfg = TraceConfig { n: 200, ..Default::default() };
        let tr = generate(&cfg);
        for w in tr.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        for r in &tr {
            assert!((cfg.prompt_min..=cfg.prompt_max).contains(&r.prompt_len));
            assert!((cfg.max_new_min..=cfg.max_new_max).contains(&r.max_new));
        }
    }

    #[test]
    fn poisson_rate_roughly_respected() {
        let cfg = TraceConfig {
            n: 2000,
            arrival: Arrival::Poisson { rate: 50.0 },
            ..Default::default()
        };
        let tr = generate(&cfg);
        let span = tr.last().unwrap().at;
        let rate = 2000.0 / span;
        assert!((35.0..70.0).contains(&rate), "{rate}");
    }

    #[test]
    fn bursty_has_higher_variance_than_poisson() {
        let mk = |arrival| {
            let tr = generate(&TraceConfig { n: 1500, arrival, ..Default::default() });
            let gaps: Vec<f64> = tr.windows(2).map(|w| w[1].at - w[0].at).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
                / gaps.len() as f64;
            var / (mean * mean) // squared coefficient of variation
        };
        let cv2_poisson = mk(Arrival::Poisson { rate: 10.0 });
        let cv2_bursty = mk(Arrival::Bursty {
            calm_rate: 2.0,
            burst_rate: 50.0,
            dwell_s: 1.0,
        });
        assert!(cv2_bursty > cv2_poisson, "{cv2_bursty} vs {cv2_poisson}");
    }

    #[test]
    fn offered_load_positive() {
        let tr = generate(&TraceConfig::default());
        assert!(offered_load(&tr) > 0.0);
    }

    #[test]
    fn zero_rate_stays_finite_and_monotone() {
        // degenerate rate parameters must not produce +inf arrival
        // times (exponential(0) = inf) — they clamp to a tiny rate
        for arrival in [
            Arrival::Poisson { rate: 0.0 },
            Arrival::Bursty { calm_rate: 0.0, burst_rate: 0.0, dwell_s: 0.0 },
        ] {
            let tr = generate(&TraceConfig { n: 16, arrival, ..Default::default() });
            assert_eq!(tr.len(), 16);
            for w in tr.windows(2) {
                assert!(w[0].at.is_finite() && w[1].at >= w[0].at);
            }
        }
    }

    #[test]
    fn inverted_length_bounds_collapse_to_min() {
        // max < min must not underflow; every length collapses to min
        let cfg = TraceConfig {
            n: 32,
            prompt_min: 10,
            prompt_max: 3,
            max_new_min: 8,
            max_new_max: 2,
            ..Default::default()
        };
        for r in generate(&cfg) {
            assert_eq!(r.prompt_len, 10);
            assert_eq!(r.max_new, 8);
        }
    }

    #[test]
    fn load_summary_degenerate_inputs_are_zero_not_panic() {
        assert_eq!(load_summary(&[], 1.0), LoadSummary::default());
        // zero / negative windows clamp instead of dividing by zero
        let tr = generate(&TraceConfig::default());
        let s = load_summary(&tr, 0.0);
        assert!(s.peak_tokens_per_s.is_finite());
        let s = load_summary(&tr, -3.0);
        assert!(s.peak_tokens_per_s.is_finite());
    }

    #[test]
    fn load_summary_peak_at_least_mean() {
        let tr = generate(&TraceConfig { n: 400, ..Default::default() });
        let s = load_summary(&tr, 1.0);
        assert!(s.span_s > 0.0);
        assert!(s.requests_per_s > 0.0);
        assert!(
            s.prompt_tokens_per_s > 0.0 && s.prompt_tokens_per_s < s.tokens_per_s,
            "prompt rate {} should be a strict share of total {}",
            s.prompt_tokens_per_s,
            s.tokens_per_s
        );
        assert!(
            s.peak_tokens_per_s >= s.tokens_per_s * 0.99,
            "peak {} below mean {}",
            s.peak_tokens_per_s,
            s.tokens_per_s
        );
        // a burstier process concentrates more tokens into the window
        let bursty = generate(&TraceConfig {
            n: 400,
            arrival: Arrival::Bursty { calm_rate: 1.0, burst_rate: 80.0, dwell_s: 1.0 },
            ..Default::default()
        });
        let sb = load_summary(&bursty, 1.0);
        assert!(sb.peak_tokens_per_s / sb.tokens_per_s > s.peak_tokens_per_s / s.tokens_per_s);
    }

    #[test]
    fn offered_load_empty_trace_is_zero_not_panic() {
        // regression: the span summary used to `.last().unwrap()` its
        // way into a panic on an empty trace
        assert_eq!(offered_load(&[]), 0.0);
        // a single instantaneous arrival is finite too (span clamp)
        let one = [TraceItem { at: 0.0, prompt_len: 4, max_new: 4 }];
        assert!(offered_load(&one).is_finite());
    }
}
