//! The serving coordinator — L3 of the ScatterMoE stack.
//!
//! ScatterMoE's GPU contribution is a *kernel*; deployed, it lives inside
//! a serving engine.  This module is that engine, in the vLLM-router
//! mold, sized to the single-device PJRT testbed:
//!
//! * [`request`]  — request/response types, generation parameters.
//! * [`batcher`]  — continuous batcher: admits requests into fixed-width
//!   decode slots, refilling slots as sequences finish (the moral
//!   equivalent of vLLM's continuous batching over a static-shape AOT
//!   decode step).
//! * [`scheduler`] — prefill/decode interleaving policy and admission
//!   control with backpressure.
//! * [`pagetable`] — refcounted free-list page allocator + reservation
//!   ledger for the paged KV cache (block-table serving layout; lazy
//!   page growth, copy-on-write prefix sharing, admission gated on
//!   unreserved pages).
//! * [`expert_stats`] — per-expert routing load telemetry (the paper's
//!   imbalance story made observable: padding waste, load CV).
//! * [`trace`]    — reproducible arrival-process generation (Poisson,
//!   bursty) for the serving experiments.
//! * [`engine`]   — ties it together around [`crate::runtime::Runtime`]:
//!   worker loop, tokenizer-in/tokenizer-out, latency metrics.

pub mod batcher;
pub mod engine;
pub mod expert_stats;
pub mod pagetable;
pub mod request;
pub mod scheduler;
pub mod trace;

pub use batcher::{Batcher, Slot, SlotState};
pub use engine::{sample_logits, Engine, EngineConfig, EngineMetrics, KvLayout};
pub use expert_stats::ExpertStats;
pub use pagetable::{PageAllocator, RESERVED_PAGE};
pub use request::{FinishReason, Request, RequestId, Response, SamplingParams};
pub use scheduler::{Scheduler, SchedulerConfig};
