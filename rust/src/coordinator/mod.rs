//! The serving coordinator — L3 of the ScatterMoE stack.
//!
//! ScatterMoE's GPU contribution is a *kernel*; deployed, it lives inside
//! a serving engine.  This module is that engine, in the vLLM-router
//! mold, sized to the single-device PJRT testbed:
//!
//! * [`request`]  — request/response types, generation parameters.
//! * [`batcher`]  — continuous batcher: admits requests into fixed-width
//!   decode slots, refilling slots as sequences finish (the moral
//!   equivalent of vLLM's continuous batching over a static-shape AOT
//!   decode step).
//! * [`scheduler`] — prefill/decode interleaving policy and admission
//!   control with backpressure.
//! * [`kvcache`]  — the KV-cache manager: page allocator + reservation
//!   ledger ([`kvcache::pagetable`]), lazy growth, copy-on-write prefix
//!   sharing, and the LRU-evicted retained prefix pool, behind the
//!   narrow admit/install/grow/release API the engine drives — plus
//!   the host memory tier ([`kvcache::host_tier`]) those lean on for
//!   overcommit (preemptive swap-out under reservation pressure),
//!   prefix-pool spill, and cross-replica prefix-KV staging.
//! * [`sampling`] — per-request greedy/temperature/top-k token
//!   sampling over one logits row (slot-isolated rng streams).
//! * [`expert_stats`] — per-expert routing load telemetry (the paper's
//!   imbalance story made observable: padding waste, load CV).
//! * [`mesh`]     — simulated expert-parallel device mesh: an expert →
//!   (device, replica set) placement table, a shortcut-connected
//!   overlap cost model (`max(compute, comm)` vs the serial
//!   `compute + comm`), and a telemetry-driven hot-expert rebalancer —
//!   with `ep_degree: 1` bit-identical to no mesh at all.
//! * [`trace`]    — reproducible arrival-process generation (Poisson,
//!   bursty) for the serving experiments.
//! * [`engine`]   — ties it together around [`crate::runtime::Runtime`]:
//!   worker loop, tokenizer-in/tokenizer-out, latency metrics.
//! * [`frontend`] — the open-loop serving front-end above the engine:
//!   typed intake/backpressure, TTFT + total-latency deadlines,
//!   transient-retry / permanent-drain fault handling, and SLO
//!   reporting (plus the artifact-free [`frontend::sim::SimEngine`]
//!   twin the seeded chaos suite runs against).
//! * [`cluster`]  — multi-replica serving above the front-end: an
//!   [`cluster::EnginePool`] of N replicas behind a prefix-affinity
//!   [`cluster::Router`] with least-loaded fallback, a shared
//!   host-side prefix store warm-starting per-replica retained pools,
//!   and replica-death drain → re-offer → bit-identical replay.

pub mod batcher;
pub mod cluster;
pub mod engine;
pub mod expert_stats;
pub mod frontend;
pub mod kvcache;
pub mod mesh;
pub mod request;
pub mod sampling;
pub mod scheduler;
pub mod trace;

pub use batcher::{Batcher, Slot, SlotState};
pub use cluster::{
    ClusterConfig, ClusterFrontend, ClusterOutcome, ClusterReport, EnginePool,
    HostPrefixStore, PrefixStoreConfig, PrefixStoreStats, ReplicaLoad, RouteDecision,
    Router, RouterPolicy,
};
pub use engine::{
    validate_chunk_config, ChunkConfigError, Engine, EngineConfig, EngineMetrics,
};
pub use frontend::faults::{fault_kind, FaultError, FaultInjector, FaultKind, FaultSite};
pub use frontend::intake::{IntakePolicy, RejectReason};
pub use frontend::sim::{SimEngine, SimEngineConfig};
pub use frontend::slo::ServeReport;
pub use frontend::{
    ArrivingRequest, ClockMode, FrontendConfig, FrontendStatus, RequestOutcome,
    RetryPolicy, ServeFrontend, ServingEngine, StreamEvent, TokenStream,
};
pub use sampling::sample_logits;
pub use expert_stats::{cv_of, ExpertStats};
pub use mesh::{
    ExpertPlacement, MeshConfig, MeshSim, MeshStats, OverlapModel, PlacementEvent,
    RebalanceConfig, Rebalancer, StepTime,
};
pub use kvcache::host_tier::{
    HostOp, HostTier, HostTierConfig, HostTierStats, PrefixKv,
};
pub use kvcache::pagetable;
pub use kvcache::pagetable::{PageAllocator, RESERVED_PAGE};
pub use kvcache::{KvCacheConfig, KvCacheManager, KvLayout, KvMetrics};
pub use request::{FinishReason, Request, RequestId, Response, SamplingParams};
pub use scheduler::{adaptive_chunk_budget, MixedStep, Scheduler, SchedulerConfig};
