//! Prefill/decode scheduling policy.
//!
//! The AOT artifacts expose two static-shape entry points: `serve_prefill`
//! (whole-batch prompt pass that also seeds the KV caches) and
//! `serve_decode` (one token for all slots).  The scheduler decides, at
//! each engine tick, whether to run a prefill (new arrivals waiting and a
//! batch-restart is worth it) or a decode step (sequences in flight).
//!
//! Because the serve artifacts prefill all `B` slots in one call (static
//! shapes — the paper's own "capacity" discussion applies), a prefill
//! restarts the batch: the policy therefore weighs queued work against
//! in-flight work, with a waiting-time bound to keep TTFT tails in check.

/// Scheduler tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Run a prefill as soon as this many slots could be filled.
    pub min_fill: usize,
    /// ... or once the oldest queued request waited this long (seconds).
    pub max_wait_s: f64,
    /// Never prefill while more than this fraction of slots decode.
    pub max_active_frac: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { min_fill: 1, max_wait_s: 0.2, max_active_frac: 0.5 }
    }
}

/// What the engine should do this tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Refill empty slots and run the prefill artifact.
    Prefill,
    /// Run one decode step for the in-flight batch.
    Decode,
    /// Nothing to do.
    Idle,
}

/// Mixed-phase step composition (chunked-prefill mode): unlike
/// [`Action`], which picks *one* phase per tick, a mixed step can admit,
/// advance prefill chunks, and decode in the same engine tick — chunked
/// prefill removes the batch-restart cost that made those alternatives.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MixedStep {
    /// Fill empty slots from the (page-admissible) queue this tick.
    pub admit: bool,
    /// Advance in-prefill slots by the step's chunk token budget.
    pub chunk: bool,
    /// Run one decode step for the in-flight batch.
    pub decode: bool,
}

impl MixedStep {
    /// True when the step does nothing — only legal with no work anywhere.
    pub fn is_idle(&self) -> bool {
        !(self.admit || self.chunk || self.decode)
    }
}

/// Adaptive prefill chunk budget (chunked-prefill mode, behind the
/// engines' `adaptive_chunking` knob): size this step's prompt-token
/// budget from the observed prompt-token arrival rate (the front-end's
/// intake window, see `ServingEngine::note_prompt_load`) and the live
/// decode population, instead of the fixed `base` budget.
///
/// Shape: the arrival rate — measured in units of base budgets per
/// second — scales the budget *up* (a prompt burst widens chunks so the
/// prefill backlog drains) while the decode share of the batch scales
/// it *down* (a busy decode batch keeps chunks narrow to protect TPOT).
/// The result is clamped to `[page_size, 4 * base]`: every chunk still
/// covers at least one KV page (the chunk-config validity floor), and a
/// burst can never starve decode entirely.
///
/// A pure, total function of its arguments — the budget schedule is
/// pinned exactly in the tests below.
pub fn adaptive_chunk_budget(
    base: usize, page_size: usize, prompt_tokens_per_s: f64,
    decode_population: usize, width: usize,
) -> usize {
    let base = base.max(1);
    let width = width.max(1);
    let decode_frac = decode_population.min(width) as f64 / width as f64;
    let demand = (prompt_tokens_per_s / base as f64).clamp(0.0, 3.0);
    let scaled = base as f64 * (1.0 + demand) * (1.0 - 0.75 * decode_frac);
    let floor = page_size.max(1);
    let cap = (4 * base).max(floor);
    (scaled as usize).clamp(floor, cap)
}

/// Pure decision function over the observable batch state.
#[derive(Clone, Debug)]
pub struct Scheduler {
    /// Tuning knobs.
    pub cfg: SchedulerConfig,
}

impl Scheduler {
    /// Scheduler with the given knobs.
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler { cfg }
    }

    /// Decide the next action.
    ///
    /// * `queued` — requests that could be admitted *right now* (the
    ///   paged engine passes the FIFO prefix whose page commitments —
    ///   fresh pages plus lazy-growth reservations, net of shareable
    ///   prefix pages — fit in the *unreserved* pool, not the raw queue
    ///   length: a page-starved queue must read as "nothing to prefill"
    ///   so the batch keeps decoding, and retirements return both pages
    ///   and reservations),
    /// * `empty_slots` — free decode slots,
    /// * `active` — slots currently decoding,
    /// * `oldest_wait_s` — waiting time of the head-of-line request.
    ///
    /// Liveness: the decision is `Idle` only when `queued == 0 &&
    /// active == 0` — whenever admissible or in-flight work exists, the
    /// engine is told to make progress (property-tested below; the
    /// page-starvation case relies on it to drain the batch rather than
    /// spin).
    pub fn decide(
        &self, queued: usize, empty_slots: usize, active: usize,
        oldest_wait_s: f64,
    ) -> Action {
        let width = empty_slots + active;
        if queued == 0 && active == 0 {
            return Action::Idle;
        }
        let fillable = queued.min(empty_slots);
        if fillable > 0 {
            let starving = oldest_wait_s >= self.cfg.max_wait_s;
            let below_active_bound =
                (active as f64) <= self.cfg.max_active_frac * width as f64;
            if fillable >= self.cfg.min_fill && (below_active_bound || starving) {
                return Action::Prefill;
            }
        }
        if active > 0 {
            Action::Decode
        } else if fillable > 0 {
            // nothing decoding; fill regardless of thresholds
            Action::Prefill
        } else {
            Action::Idle
        }
    }

    /// Decide the mixed-phase step (chunked-prefill mode).
    ///
    /// With chunked prefill a prefill no longer restarts the whole
    /// batch, so `min_fill` / `max_active_frac` gating would only add
    /// queueing delay: the policy admits whenever the page-admissible
    /// FIFO prefix and an empty slot exist, advances chunks whenever
    /// in-prefill slots exist, and decodes whenever decoding slots
    /// exist — all in the same step.
    ///
    /// Liveness mirrors [`Self::decide`]: the step is idle only when
    /// no admissible, in-prefill, or decoding work exists (a
    /// page-starved queue with a busy batch reads `admissible == 0`,
    /// so the step decodes and retirement frees pages).
    pub fn decide_mixed(
        &self, admissible: usize, empty_slots: usize, chunking: usize, decoding: usize,
    ) -> MixedStep {
        MixedStep {
            admit: admissible.min(empty_slots) > 0,
            chunk: chunking > 0,
            decode: decoding > 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Scheduler {
        Scheduler::new(SchedulerConfig { min_fill: 2, max_wait_s: 1.0, max_active_frac: 0.5 })
    }

    #[test]
    fn idle_when_no_work() {
        assert_eq!(sched().decide(0, 8, 0, 0.0), Action::Idle);
    }

    #[test]
    fn prefill_when_queue_and_empty_batch() {
        assert_eq!(sched().decide(5, 8, 0, 0.0), Action::Prefill);
    }

    #[test]
    fn decode_when_batch_busy_and_queue_small() {
        // 6 of 8 active (> 50%), only 1 fillable (< min_fill) → decode
        assert_eq!(sched().decide(1, 2, 6, 0.0), Action::Decode);
    }

    #[test]
    fn starvation_forces_prefill() {
        // active above bound, but head-of-line waited too long
        assert_eq!(sched().decide(2, 2, 6, 5.0), Action::Prefill);
    }

    #[test]
    fn single_straggler_fills_when_idle() {
        // queue=1 < min_fill but nothing decoding → prefill anyway
        assert_eq!(sched().decide(1, 8, 0, 0.0), Action::Prefill);
    }

    #[test]
    fn drains_in_flight_work() {
        assert_eq!(sched().decide(0, 6, 2, 0.0), Action::Decode);
    }

    #[test]
    fn never_idle_while_work_exists() {
        // Liveness sweep: any state with admissible or in-flight work
        // must yield progress (guards the page-starvation wait states —
        // run_to_completion spins forever on a wrong Idle).
        let s = sched();
        for width in 1..=4usize {
            for active in 0..=width {
                let empty = width - active;
                for queued in 0..4usize {
                    for wait in [0.0, 10.0] {
                        let a = s.decide(queued, empty, active, wait);
                        if queued > 0 || active > 0 {
                            assert_ne!(
                                a,
                                Action::Idle,
                                "idle at queued={queued} empty={empty} active={active}"
                            );
                        } else {
                            assert_eq!(a, Action::Idle);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn mixed_step_composes_all_phases() {
        let s = sched();
        // admissible work, chunking slots, and decoders: all three fire
        let step = s.decide_mixed(2, 1, 1, 2);
        assert_eq!(step, MixedStep { admit: true, chunk: true, decode: true });
        // page-starved queue with a busy batch: decode only (liveness —
        // retirement frees the pages the head is waiting for)
        let step = s.decide_mixed(0, 1, 0, 3);
        assert_eq!(step, MixedStep { admit: false, chunk: false, decode: true });
        // no empty slot: admission waits even with an admissible head
        let step = s.decide_mixed(2, 0, 1, 3);
        assert_eq!(step, MixedStep { admit: false, chunk: true, decode: true });
    }

    #[test]
    fn mixed_step_never_idle_while_work_exists() {
        // Liveness sweep over the mixed decision: any state with
        // admissible, in-prefill, or decoding work must make progress.
        let s = sched();
        for empty in 0..=3usize {
            for admissible in 0..=3usize {
                for chunking in 0..=3usize {
                    for decoding in 0..=3usize {
                        let step = s.decide_mixed(admissible, empty, chunking, decoding);
                        let work =
                            admissible.min(empty) > 0 || chunking > 0 || decoding > 0;
                        assert_eq!(
                            !step.is_idle(),
                            work,
                            "admissible={admissible} empty={empty} \
                             chunking={chunking} decoding={decoding}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn adaptive_budget_closed_form_pins() {
        // demand is measured in base budgets per second; decode share
        // multiplies the result down — every case below is exact in f64
        assert_eq!(adaptive_chunk_budget(16, 8, 0.0, 0, 4), 16, "idle = base");
        assert_eq!(adaptive_chunk_budget(16, 8, 16.0, 0, 4), 32, "demand 1 doubles");
        assert_eq!(adaptive_chunk_budget(16, 8, 1e9, 0, 4), 64, "capped at 4x base");
        assert_eq!(
            adaptive_chunk_budget(16, 8, 0.0, 4, 4),
            8,
            "full decode batch floors at one page"
        );
        assert_eq!(
            adaptive_chunk_budget(16, 8, 16.0, 2, 4),
            20,
            "half-decode burst: 32 * 0.625"
        );
        // degenerate geometries stay total: zero width, page floor above
        // the cap, garbage rates
        assert_eq!(adaptive_chunk_budget(16, 8, 0.0, 0, 0), 16);
        assert_eq!(adaptive_chunk_budget(2, 32, 0.0, 0, 4), 32, "floor wins over cap");
        assert_eq!(adaptive_chunk_budget(16, 8, f64::NAN, 0, 4), 8, "NaN rate floors");
    }

    #[test]
    fn adaptive_budget_schedule_on_a_bursty_trace() {
        use crate::coordinator::trace::{generate, load_summary, Arrival, TraceConfig};
        let trace = generate(&TraceConfig {
            n: 96,
            arrival: Arrival::Bursty { calm_rate: 2.0, burst_rate: 40.0, dwell_s: 0.5 },
            seed: 9,
            ..Default::default()
        });
        let load = load_summary(&trace, 0.5);
        assert!(load.prompt_tokens_per_s > 0.0, "bursty trace offers prompt work");
        let (base, page, width) = (16, 8, 4);
        // the budget schedule over the decode population at the trace's
        // mean prompt rate: monotone non-increasing in decode share,
        // always within [page, 4 * base]
        let sched: Vec<usize> = (0..=width)
            .map(|d| adaptive_chunk_budget(base, page, load.prompt_tokens_per_s, d, width))
            .collect();
        for pair in sched.windows(2) {
            assert!(pair[0] >= pair[1], "budget must shrink with decode load: {sched:?}");
        }
        for &b in &sched {
            assert!((page..=4 * base).contains(&b), "clamp violated: {sched:?}");
        }
        // a burst widens the budget relative to the calm mean
        let calm = adaptive_chunk_budget(base, page, load.prompt_tokens_per_s, 0, width);
        let burst = adaptive_chunk_budget(base, page, load.peak_tokens_per_s, 0, width);
        assert!(
            burst >= calm,
            "peak-rate budget {burst} below mean-rate budget {calm}"
        );
    }

    #[test]
    fn page_starved_queue_decodes_instead_of_prefilling() {
        // the engine reports admissible=0 when the head-of-line request
        // cannot get pages; the batch must keep decoding (which retires
        // slots and frees pages) rather than attempt an empty prefill
        assert_eq!(sched().decide(0, 2, 6, 99.0), Action::Decode);
    }
}
