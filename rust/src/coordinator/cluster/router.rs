//! Prefix-affinity request router.
//!
//! Routing wants two things in tension: *affinity* (requests sharing a
//! prompt prefix should land on the same replica, so its retained
//! prefix pool — not N cold pools — serves the hits) and *balance*
//! (never pile onto a busy or page-starved replica just because the
//! hash says so).  [`Router::route`] resolves it lexicographically:
//! the prefix-hash replica wins while it is alive, its queue is
//! shallow, and its page pool has headroom; otherwise a deterministic
//! least-loaded scan picks the fallback.  The router holds no mutable
//! state — the same prompt and the same loads always produce the same
//! decision, which the seeded chaos runs rely on.

/// Tunables for the affinity/balance trade-off.
#[derive(Clone, Copy, Debug)]
pub struct RouterPolicy {
    /// Prompt tokens hashed for the affinity decision.  Requests that
    /// agree on this many leading tokens (a shared system prompt)
    /// map to the same preferred replica.
    pub affinity_tokens: usize,
    /// Outstanding-work depth beyond which the preferred replica is
    /// considered overloaded and the least-loaded fallback takes over.
    pub max_affinity_queue: usize,
    /// Minimum reclaimable-page fraction the preferred replica must
    /// hold; below it (page pressure) the fallback takes over.  Dense
    /// layouts report no budget and never trip this.
    pub min_affinity_free_frac: f64,
}

impl Default for RouterPolicy {
    fn default() -> Self {
        RouterPolicy {
            affinity_tokens: 16,
            max_affinity_queue: 8,
            min_affinity_free_frac: 0.1,
        }
    }
}

/// One replica's load snapshot, as the router sees it.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaLoad {
    /// False once the replica halted (dead replicas never route).
    pub alive: bool,
    /// Outstanding work: queued + in-flight requests.
    pub queue_len: usize,
    /// Reclaimable / usable pool pages (`None` on dense layouts).
    pub page_budget: Option<(usize, usize)>,
}

impl ReplicaLoad {
    /// Reclaimable fraction of the page pool; dense layouts (no
    /// budget) count as fully free.
    fn free_frac(&self) -> f64 {
        match self.page_budget {
            Some((_, 0)) | None => 1.0,
            Some((reclaimable, usable)) => reclaimable as f64 / usable as f64,
        }
    }

    /// Reclaimable pages for the least-loaded tie-break (dense =
    /// unbounded).
    fn free_pages(&self) -> usize {
        self.page_budget.map_or(usize::MAX, |(reclaimable, _)| reclaimable)
    }
}

/// Where one request goes, and why.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    /// Index of the chosen replica.
    pub replica: usize,
    /// True when the prefix-hash preference held; false when load or
    /// death forced the least-loaded fallback.
    pub affinity: bool,
}

/// The stateless prefix-affinity router (see module docs).
#[derive(Clone, Copy, Debug, Default)]
pub struct Router {
    policy: RouterPolicy,
}

impl Router {
    /// A router with the given policy.
    pub fn new(policy: RouterPolicy) -> Self {
        Router { policy }
    }

    /// The policy in force.
    pub fn policy(&self) -> &RouterPolicy {
        &self.policy
    }

    /// The prefix-hash preferred replica for `prompt` among `n`
    /// replicas: FNV-1a over the first `affinity_tokens` tokens, so
    /// shared system prompts concentrate on one retained prefix pool.
    pub fn preferred(&self, prompt: &[i32], n: usize) -> usize {
        debug_assert!(n > 0, "routing over an empty pool");
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        for &t in prompt.iter().take(self.policy.affinity_tokens.max(1)) {
            // zero-extend through u32 so negative token ids hash the
            // same on every platform
            h ^= u64::from(t as u32);
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
        (h % n as u64) as usize
    }

    /// Route one request: the preferred replica while it is alive,
    /// shallow, and page-free; else the deterministic least-loaded
    /// fallback (shallowest queue, then most reclaimable pages, then
    /// lowest index).  `None` only when no replica is alive.
    pub fn route(&self, prompt: &[i32], loads: &[ReplicaLoad]) -> Option<RouteDecision> {
        if loads.is_empty() || loads.iter().all(|l| !l.alive) {
            return None;
        }
        let preferred = self.preferred(prompt, loads.len());
        let p = &loads[preferred];
        if p.alive
            && p.queue_len <= self.policy.max_affinity_queue
            && p.free_frac() >= self.policy.min_affinity_free_frac
        {
            return Some(RouteDecision { replica: preferred, affinity: true });
        }
        let replica = loads
            .iter()
            .enumerate()
            .filter(|(_, l)| l.alive)
            .min_by_key(|&(i, l)| (l.queue_len, std::cmp::Reverse(l.free_pages()), i))
            .map(|(i, _)| i)
            .expect("an alive replica exists");
        Some(RouteDecision { replica, affinity: false })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle(n: usize) -> Vec<ReplicaLoad> {
        vec![ReplicaLoad { alive: true, queue_len: 0, page_budget: Some((20, 20)) }; n]
    }

    #[test]
    fn shared_prefixes_concentrate_on_one_replica() {
        let router = Router::new(RouterPolicy { affinity_tokens: 8, ..Default::default() });
        let system: Vec<i32> = (100..108).collect();
        let mut a = system.clone();
        a.extend([1, 2, 3]);
        let mut b = system.clone();
        b.extend([9, 9, 9, 9]);
        assert_eq!(router.preferred(&a, 3), router.preferred(&b, 3));
        // and the full route agrees when that replica is healthy
        let da = router.route(&a, &idle(3)).unwrap();
        let db = router.route(&b, &idle(3)).unwrap();
        assert_eq!(da.replica, db.replica);
        assert!(da.affinity && db.affinity);
        // distinct prefixes spread: over many prompts, >1 replica is hit
        let hit: std::collections::HashSet<usize> = (0..32)
            .map(|k| router.preferred(&[k * 17 + 1; 8], 3))
            .collect();
        assert!(hit.len() > 1, "hash degenerated to one replica");
    }

    #[test]
    fn overloaded_or_starved_preferred_falls_back_least_loaded() {
        let router = Router::new(RouterPolicy {
            affinity_tokens: 4,
            max_affinity_queue: 2,
            min_affinity_free_frac: 0.25,
        });
        let prompt = [5, 6, 7, 8];
        let p = router.preferred(&prompt, 3);
        // deep queue on the preferred replica trips the fallback
        let mut loads = idle(3);
        loads[p].queue_len = 3;
        let d = router.route(&prompt, &loads).unwrap();
        assert!(!d.affinity);
        assert_ne!(d.replica, p, "fallback left the overloaded replica");
        // page starvation trips it too
        let mut loads = idle(3);
        loads[p].page_budget = Some((2, 20)); // 10% < 25%
        let d = router.route(&prompt, &loads).unwrap();
        assert!(!d.affinity);
        assert_ne!(d.replica, p);
        // the fallback itself is deterministic: shallowest queue wins,
        // and equal queues break to the most reclaimable pages
        let mut loads = idle(3);
        loads[p].queue_len = 5;
        for (i, l) in loads.iter_mut().enumerate() {
            if i != p {
                l.page_budget = Some((3 + i, 20));
            }
        }
        let d = router.route(&prompt, &loads).unwrap();
        let expect = if p == 2 { 1 } else { 2 }; // highest index != p has most free
        assert_eq!(d.replica, expect, "most free pages won the tie");
    }

    #[test]
    fn dead_replicas_never_route() {
        let router = Router::default();
        let prompt = [1, 2, 3];
        let p = router.preferred(&prompt, 2);
        let mut loads = idle(2);
        loads[p].alive = false;
        let d = router.route(&prompt, &loads).unwrap();
        assert_ne!(d.replica, p);
        assert!(!d.affinity);
        // all dead: nowhere to route
        loads[1 - p].alive = false;
        assert!(router.route(&prompt, &loads).is_none());
    }

    #[test]
    fn routing_is_a_pure_function() {
        let router = Router::default();
        let loads = idle(4);
        for k in 0..16 {
            let prompt = vec![k; 24];
            let a = router.route(&prompt, &loads).unwrap();
            let b = router.route(&prompt, &loads).unwrap();
            assert_eq!(a, b);
        }
    }
}
