//! Multi-replica serving: a fault-tolerant engine pool behind a
//! prefix-affinity router.
//!
//! One [`ServeFrontend`] drives one engine; this module is the layer
//! above it that a multi-GPU service needs.  [`ClusterFrontend`] owns
//! the global clock and arrival stream and fans requests out to an
//! [`EnginePool`] of N replicas — each a full `ServeFrontend` with its
//! own intake gate, deadlines, and fault recovery — through three
//! separately-testable pieces:
//!
//!   * **routing** — every due arrival passes the [`Router`]: the
//!     prompt-prefix hash concentrates shared system prompts on one
//!     replica's retained prefix pool, with a deterministic
//!     least-loaded fallback on queue depth / free-page fraction
//!     (see `router.rs`).
//!   * **prefix sharing across replicas** — completed prompts upload
//!     their page-aligned prefix to the [`HostPrefixStore`] on miss —
//!     tokens always, plus the actual KV page bytes when the resolving
//!     replica has a host KV tier to export them from
//!     ([`ServingEngine::export_prefix`]); a routed request that hits
//!     the store warm-starts the prefix into its target replica's
//!     retained pool before submission
//!     ([`ServingEngine::warm_prefix_kv`], shipping the stored bytes
//!     when present), so a re-routed or restarted replica serves the
//!     same system prompts without a cold prefill (see
//!     `prefix_store.rs`).
//!   * **replica death → drain → re-offer → replay** — a replica that
//!     halts (permanent fault escalation, or a scripted kill via
//!     [`ClusterFrontend::kill_replica_at`]) drains through the
//!     existing `abort_all` path into typed
//!     [`RequestOutcome::Drained`] outcomes.  The cluster intercepts
//!     those instead of recording them: each drained request is
//!     *re-offered* to a healthy replica, where seed-based replay
//!     ([`crate::coordinator::request::SamplingParams::seed`]) makes
//!     the re-served tokens bit-identical to an undisturbed run.  Its
//!     terminal outcome carries the `re_routed` flag and counts
//!     exactly once in [`ServeReport::accounted`].  Only when no
//!     healthy replica remains does `Drained` become terminal.
//!
//! Per-token streaming stays a single-replica concern: the cluster
//! forces `stream: false` on its replicas (a re-offered request would
//! otherwise need cross-replica stream splicing — out of scope here).
//!
//! With [`SimEngine`] replicas ([`ClusterFrontend::sim`]) the whole
//! cluster — arrivals, routing, kills, drains, re-offers — runs on the
//! virtual clock, artifact-free and deterministic under its seeds;
//! `rust/tests/chaos_props.rs` property-tests allocator conservation
//! on every replica after every step and token-equality of surviving
//! completions against a fault-free single-replica run.

pub mod prefix_store;
pub mod router;

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Instant;

use super::frontend::sim::{SimEngine, SimEngineConfig};
use super::frontend::slo::ServeReport;
use super::frontend::{
    ArrivingRequest, ClockMode, FrontendConfig, FrontendStatus, RequestOutcome,
    ServeFrontend, ServingEngine,
};

pub use prefix_store::{HostPrefixStore, PrefixStoreConfig, PrefixStoreStats};
pub use router::{ReplicaLoad, RouteDecision, Router, RouterPolicy};

/// Cluster configuration: the per-replica front-end config plus the
/// routing and host-prefix-store policies.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterConfig {
    /// Per-replica front-end config (intake, deadlines, retry, clock).
    /// The clock also drives the cluster's own routing loop; `stream`
    /// is forced off (see module docs).
    pub frontend: FrontendConfig,
    /// Prefix-affinity routing policy.
    pub router: RouterPolicy,
    /// Host prefix store geometry (match `page_tokens` to the
    /// replicas' KV page size).
    pub store: PrefixStoreConfig,
}

/// N engine replicas, each wrapped in its own [`ServeFrontend`], with
/// liveness tracking.  The pool is dumb on purpose: routing lives in
/// [`Router`], drain/re-offer policy in [`ClusterFrontend`].
pub struct EnginePool<E: ServingEngine> {
    replicas: Vec<PoolReplica<E>>,
}

struct PoolReplica<E: ServingEngine> {
    fe: ServeFrontend<E>,
    alive: bool,
}

impl<E: ServingEngine> EnginePool<E> {
    /// Wrap each engine in a front-end with `cfg`.
    pub fn new(engines: Vec<E>, cfg: FrontendConfig) -> Self {
        EnginePool {
            replicas: engines
                .into_iter()
                .map(|e| PoolReplica { fe: ServeFrontend::new(e, cfg), alive: true })
                .collect(),
        }
    }

    /// Number of replicas (dead ones included).
    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    /// True when the pool holds no replicas at all.
    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    /// Whether replica `i` is still serving.
    pub fn alive(&self, i: usize) -> bool {
        self.replicas[i].alive
    }

    /// True while at least one replica is serving.
    pub fn any_alive(&self) -> bool {
        self.replicas.iter().any(|r| r.alive)
    }

    /// Replica `i`'s front-end.
    pub fn frontend(&self, i: usize) -> &ServeFrontend<E> {
        &self.replicas[i].fe
    }

    /// Mutable access to replica `i`'s front-end (tests inject faults
    /// through here).
    pub fn frontend_mut(&mut self, i: usize) -> &mut ServeFrontend<E> {
        &mut self.replicas[i].fe
    }

    /// Load snapshot of every replica, for the router.
    pub fn loads(&self) -> Vec<ReplicaLoad> {
        self.replicas
            .iter()
            .map(|r| ReplicaLoad {
                alive: r.alive,
                queue_len: r.fe.engine().queue_len() + r.fe.live_ids().len(),
                page_budget: r.fe.engine().page_budget(),
            })
            .collect()
    }

    fn mark_dead(&mut self, i: usize) {
        self.replicas[i].alive = false;
    }
}

/// One request's terminal outcome at cluster level.
#[derive(Clone, Debug)]
pub struct ClusterOutcome {
    /// The arrival's caller-chosen tag.
    pub tag: u64,
    /// Replica the outcome landed on (for a re-offered request: the
    /// replica that finally resolved it, not the one that drained).
    pub replica: usize,
    /// True when the request was re-offered after a replica death —
    /// the satellite flag: one accounted outcome, plus this bit.
    pub re_routed: bool,
    /// The terminal outcome itself.
    pub outcome: RequestOutcome,
}

/// End-of-run cluster accounting: the merged [`ServeReport`] plus the
/// cluster-only dimensions (per-replica splits, re-offers, store
/// traffic, routing mix).
#[derive(Clone, Debug)]
pub struct ClusterReport {
    /// Every outcome folded into one report.  `re_routed` counts the
    /// flagged outcomes; `accounted()` still covers each request
    /// exactly once.
    pub merged: ServeReport,
    /// The same outcomes split by resolving replica.
    pub per_replica: Vec<ServeReport>,
    /// Re-offer events (≥ `merged.re_routed`: a request re-offered
    /// twice — its second home also died — counts twice here, once
    /// there).
    pub reroutes: u64,
    /// Replicas dead by end of run.
    pub replicas_dead: usize,
    /// Host prefix store traffic.
    pub store: PrefixStoreStats,
    /// Arrivals routed by prefix affinity.
    pub affinity_hits: u64,
    /// Arrivals routed by the least-loaded fallback.
    pub affinity_fallbacks: u64,
}

/// Open-loop driver over an [`EnginePool`] (see module docs).
pub struct ClusterFrontend<E: ServingEngine> {
    pool: EnginePool<E>,
    router: Router,
    store: HostPrefixStore,
    clock: ClockMode,
    started: Instant,
    vnow: f64,
    arrivals: VecDeque<ArrivingRequest>,
    /// Every routed request, by tag, for replay on re-offer.
    requests: HashMap<u64, ArrivingRequest>,
    /// Tags routed but not yet terminal.
    open: HashSet<u64>,
    /// Tags re-offered at least once.
    re_routed: HashSet<u64>,
    outcomes: Vec<ClusterOutcome>,
    /// Scripted deaths: `(replica, cluster_time_s)`.
    kills: Vec<(usize, f64)>,
    reroutes: u64,
    replicas_dead: usize,
    affinity_hits: u64,
    affinity_fallbacks: u64,
    steps: u64,
}

impl<E: ServingEngine> ClusterFrontend<E> {
    /// A cluster over the given engines.  Panics on an empty pool.
    pub fn new(engines: Vec<E>, cfg: ClusterConfig) -> Self {
        assert!(!engines.is_empty(), "a cluster needs at least one replica");
        let mut fe_cfg = cfg.frontend;
        // per-token streaming is a single-replica concern (module docs)
        fe_cfg.stream = false;
        ClusterFrontend {
            pool: EnginePool::new(engines, fe_cfg),
            router: Router::new(cfg.router),
            store: HostPrefixStore::new(cfg.store),
            clock: fe_cfg.clock,
            started: Instant::now(),
            vnow: 0.0,
            arrivals: VecDeque::new(),
            requests: HashMap::new(),
            open: HashSet::new(),
            re_routed: HashSet::new(),
            outcomes: Vec::new(),
            kills: Vec::new(),
            reroutes: 0,
            replicas_dead: 0,
            affinity_hits: 0,
            affinity_fallbacks: 0,
            steps: 0,
        }
    }

    /// The replica pool (tests audit per-replica allocators here).
    pub fn pool(&self) -> &EnginePool<E> {
        &self.pool
    }

    /// Mutable pool access (tests inject per-replica faults here).
    pub fn pool_mut(&mut self) -> &mut EnginePool<E> {
        &mut self.pool
    }

    /// The host prefix store.
    pub fn store(&self) -> &HostPrefixStore {
        &self.store
    }

    /// Terminal outcomes recorded so far, in resolution order.
    pub fn outcomes(&self) -> &[ClusterOutcome] {
        &self.outcomes
    }

    /// Cluster steps taken (tests bound runaway loops on this).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Current time on the configured clock, seconds from run start.
    pub fn now(&self) -> f64 {
        match self.clock {
            ClockMode::Wall => self.started.elapsed().as_secs_f64(),
            ClockMode::Virtual { .. } => self.vnow,
        }
    }

    /// Load arrivals into the global stream (merged, sorted by time).
    pub fn push_arrivals(&mut self, items: impl IntoIterator<Item = ArrivingRequest>) {
        self.arrivals.extend(items);
        self.arrivals
            .make_contiguous()
            .sort_by(|a, b| a.at.total_cmp(&b.at));
    }

    /// Script replica `replica`'s death at cluster time `at_s`: the
    /// first step at or past that time force-drains it and re-offers
    /// its admitted requests to healthy replicas.
    pub fn kill_replica_at(&mut self, replica: usize, at_s: f64) {
        assert!(replica < self.pool.len(), "no such replica");
        self.kills.push((replica, at_s));
    }

    /// One cluster step: fire due scripted kills, route due arrivals,
    /// step every live replica once (harvesting outcomes and handling
    /// deaths), then advance the clock.
    pub fn step(&mut self) -> FrontendStatus {
        self.steps += 1;
        let now = self.now();

        // 1. scripted kills due at this time
        let due: Vec<usize> = self
            .kills
            .iter()
            .filter(|&&(r, t)| t <= now && self.pool.alive(r))
            .map(|&(r, _)| r)
            .collect();
        self.kills.retain(|&(r, t)| t > now && self.pool.alive(r));
        for r in due {
            if self.pool.alive(r) {
                self.pool.frontend_mut(r).force_drain("scripted replica death");
                self.handle_death(r);
            }
        }

        // 2. route due arrivals (parked while no replica is alive)
        while self.pool.any_alive() && self.arrivals.front().is_some_and(|a| a.at <= now)
        {
            let arr = self.arrivals.pop_front().expect("front just checked");
            self.dispatch(arr);
        }

        // 3. step every live replica once; harvest its outcomes
        let mut any_running = false;
        for r in 0..self.pool.len() {
            if !self.pool.alive(r) {
                continue;
            }
            let status = self.pool.frontend_mut(r).step();
            match status {
                FrontendStatus::Halted => self.handle_death(r),
                FrontendStatus::Running => {
                    any_running = true;
                    self.harvest(r);
                }
                FrontendStatus::Done => self.harvest(r),
            }
        }

        // 4. advance the cluster clock
        match self.clock {
            ClockMode::Virtual { tick_s } => {
                if any_running {
                    self.vnow += tick_s;
                } else if let Some(a) = self.arrivals.front() {
                    // every replica idle: jump to the next arrival
                    self.vnow = self.vnow.max(a.at);
                } else if let Some(t) =
                    self.kills.iter().map(|&(_, t)| t).reduce(f64::min)
                {
                    // …or to the next scripted kill, so a kill after
                    // the last arrival still fires
                    self.vnow = self.vnow.max(t);
                }
            }
            ClockMode::Wall => {
                if !any_running {
                    if let Some(a) = self.arrivals.front() {
                        let gap = (a.at - self.now()).clamp(0.0, 0.05);
                        if gap > 0.0 {
                            std::thread::sleep(std::time::Duration::from_secs_f64(gap));
                        }
                    }
                }
            }
        }

        // 5. status
        if self.arrivals.is_empty() && self.open.is_empty() {
            return FrontendStatus::Done;
        }
        if !self.pool.any_alive() {
            return FrontendStatus::Halted;
        }
        FrontendStatus::Running
    }

    /// Drive steps until the run completes or halts, then report.
    pub fn run(&mut self) -> ClusterReport {
        loop {
            match self.step() {
                FrontendStatus::Running => {}
                FrontendStatus::Done | FrontendStatus::Halted => break,
            }
        }
        self.report()
    }

    /// Route one arrival: pick a replica, warm its prefix pool from
    /// the host store, and hand it to that replica's front-end.
    fn dispatch(&mut self, mut arr: ArrivingRequest) {
        let loads = self.pool.loads();
        let Some(decision) = self.router.route(&arr.prompt, &loads) else {
            // no healthy replica: park it back; the run halts with
            // these counted unserved
            self.arrivals.push_front(arr);
            return;
        };
        if decision.affinity {
            self.affinity_hits += 1;
        } else {
            self.affinity_fallbacks += 1;
        }
        if self.store.probe(&arr.prompt) > 0 {
            // ship stored KV bytes when the store has them; a replica
            // without a host tier ignores the payload and warms
            // logically (the simulator path, where tokens regenerate)
            let payload = self.store.payload_for(&arr.prompt);
            let warmed = self
                .pool
                .frontend_mut(decision.replica)
                .engine_mut()
                .warm_prefix_kv(&arr.prompt, payload.as_ref());
            self.store.record_warm(warmed);
            if warmed > 0 {
                if let Some(bytes) = payload.as_ref().and_then(|kv| kv.bytes.as_ref())
                {
                    self.store.record_download(warmed, bytes.len());
                }
            }
        }
        self.requests.insert(arr.tag, arr.clone());
        self.open.insert(arr.tag);
        // due immediately on the replica's own clock (its front-end
        // stamps submission time when it offers the request)
        arr.at = 0.0;
        self.pool.frontend_mut(decision.replica).push_arrivals([arr]);
    }

    /// Record replica `r`'s freshly harvested outcomes.  Only called
    /// while `r` is alive, so no `Drained` outcome can appear here — a
    /// front-end only drains when it halts, and halted replicas route
    /// through [`ClusterFrontend::handle_death`], which owns the
    /// re-offer decision.
    fn harvest(&mut self, r: usize) {
        for (tag, outcome) in self.pool.frontend_mut(r).take_outcomes() {
            self.record(r, tag, outcome);
        }
    }

    /// A replica died: mark it, then re-offer every request its drain
    /// surfaced — plus its not-yet-offered arrivals — to healthy
    /// replicas.  Non-drain outcomes it resolved before dying (same
    /// step rejections, expiries) stay terminal.  With no healthy
    /// replica left, drains become terminal and arrivals park back on
    /// the global queue as unserved.
    fn handle_death(&mut self, r: usize) {
        self.pool.mark_dead(r);
        self.replicas_dead += 1;
        let harvested = self.pool.frontend_mut(r).take_outcomes();
        let unserved = self.pool.frontend_mut(r).take_unserved();
        for (tag, outcome) in harvested {
            match outcome {
                RequestOutcome::Drained(_) if self.pool.any_alive() => {
                    self.re_offer(tag);
                }
                outcome => self.record(r, tag, outcome),
            }
        }
        for arr in unserved {
            if self.pool.any_alive() {
                // an assigned-but-unoffered request replays wherever
                // it lands; it counts as re-routed all the same
                self.reroutes += 1;
                self.re_routed.insert(arr.tag);
                self.dispatch(arr);
            } else {
                self.open.remove(&arr.tag);
                self.arrivals.push_front(arr);
            }
        }
    }

    /// Re-offer a drained request to a healthy replica.  Replay is
    /// bit-identical by construction: the clone carries the original
    /// prompt and `SamplingParams` (seed included), and generated
    /// tokens are a pure function of those.
    fn re_offer(&mut self, tag: u64) {
        let arr = self
            .requests
            .get(&tag)
            .expect("drained request was routed through dispatch")
            .clone();
        self.reroutes += 1;
        self.re_routed.insert(tag);
        self.dispatch(arr);
    }

    /// Record one terminal outcome; completions feed the host prefix
    /// store (upload-on-miss).  A live resolving replica with a host
    /// KV tier also exports the actual KV bytes of the prefix it just
    /// served, so the store can ship them on the next warm-start; a
    /// dead replica (drain-path completions) falls back to the
    /// token-only offer.
    fn record(&mut self, replica: usize, tag: u64, outcome: RequestOutcome) {
        if matches!(outcome, RequestOutcome::Completed(_)) {
            if let Some(prompt) = self.requests.get(&tag).map(|a| a.prompt.clone()) {
                let kv = self.pool.alive(replica).then(|| {
                    self.pool.frontend_mut(replica).engine_mut().export_prefix(&prompt)
                });
                self.store.offer_with_payload(&prompt, kv.flatten());
            }
        }
        self.open.remove(&tag);
        self.outcomes.push(ClusterOutcome {
            tag,
            replica,
            re_routed: self.re_routed.contains(&tag),
            outcome,
        });
    }

    /// Fold the run into a [`ClusterReport`].  Meaningful after the
    /// run reaches `Done` or `Halted` (mid-run it reflects work so
    /// far).
    pub fn report(&self) -> ClusterReport {
        // per-replica base: its own front-end report (clock span,
        // ticks, retries, fatal) — outcome counters are zero there
        // because the cluster harvested them, so fold ours back in
        let mut per_replica: Vec<ServeReport> =
            (0..self.pool.len()).map(|r| self.pool.frontend(r).report()).collect();
        let mut merged = ServeReport {
            wall_s: self.now(),
            ticks: per_replica.iter().map(|p| p.ticks).sum(),
            unserved: self.arrivals.len() as u64,
            retries: per_replica.iter().map(|p| p.retries).sum(),
            fatal: (!self.pool.any_alive()).then(|| "every replica dead".to_string()),
            ..Default::default()
        };
        for co in &self.outcomes {
            merged.record_outcome(&co.outcome);
            per_replica[co.replica].record_outcome(&co.outcome);
            if co.re_routed {
                merged.re_routed += 1;
                per_replica[co.replica].re_routed += 1;
            }
        }
        ClusterReport {
            merged,
            per_replica,
            reroutes: self.reroutes,
            replicas_dead: self.replicas_dead,
            store: *self.store.stats(),
            affinity_hits: self.affinity_hits,
            affinity_fallbacks: self.affinity_fallbacks,
        }
    }
}

impl ClusterFrontend<SimEngine> {
    /// An artifact-free simulated cluster: `replicas` independent
    /// [`SimEngine`]s (each with its own paged KV pool) under one
    /// router — the `SimCluster` twin the chaos suite drives.  Panics
    /// if the sim config is invalid or `replicas` is 0.
    pub fn sim(replicas: usize, sim_cfg: SimEngineConfig, mut cfg: ClusterConfig) -> Self {
        // keep store pages aligned with the simulated KV pools
        cfg.store.page_tokens = sim_cfg.page_size;
        let engines: Vec<SimEngine> =
            (0..replicas).map(|_| SimEngine::new(sim_cfg)).collect();
        ClusterFrontend::new(engines, cfg)
    }
}
