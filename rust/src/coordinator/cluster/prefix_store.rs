//! Shared host-side prefix store backing the per-replica retained
//! prefix pools.
//!
//! One replica's retained pool dies with it; a shared system prompt
//! re-routed after a replica death would otherwise prefill from
//! scratch on its new home.  The store keeps the *page-aligned token
//! prefixes* of completed prompts host-side: a completion uploads its
//! prefix on miss, and routing probes the store so the target replica
//! can warm-start the prefix into its own retained pool
//! ([`crate::coordinator::frontend::ServingEngine::warm_prefix`] →
//! `KvCacheManager::preload_prefix`) before the request is offered.
//!
//! Like the device pools, the store is bounded and LRU-evicted, and
//! every page crossing it is counted (upload = replica→host on
//! completion, download = host→replica on warm-start) in the same
//! spirit as the runtime's `TransferTotals` — the cluster bench
//! reports these beside goodput.  The store holds tokens, not KV: on
//! the simulator that is the whole truth (sim tokens are a pure
//! function of seed and prompt), and on the real engine the byte
//! counts price the future device upload path (see ROADMAP).

/// Host prefix store geometry and accounting config.
#[derive(Clone, Copy, Debug)]
pub struct PrefixStoreConfig {
    /// Tokens per stored page — match the replicas' KV page size so
    /// warm-started pages line up with the device pools.
    pub page_tokens: usize,
    /// Resident-page bound; least-recently-used entries evict past it.
    pub capacity_pages: usize,
    /// KV bytes one token occupies, for transfer accounting only.
    pub bytes_per_token: usize,
}

impl Default for PrefixStoreConfig {
    fn default() -> Self {
        PrefixStoreConfig { page_tokens: 16, capacity_pages: 256, bytes_per_token: 256 }
    }
}

/// Monotonic transfer / hit counters for the store.
#[derive(Clone, Copy, Debug, Default)]
pub struct PrefixStoreStats {
    /// Upload events (completed prompts that added pages).
    pub uploads: u64,
    /// Pages uploaded replica→host.
    pub uploaded_pages: u64,
    /// Bytes uploaded replica→host.
    pub uploaded_bytes: u64,
    /// Routing probes that found a stored prefix.
    pub hits: u64,
    /// Routing probes that found nothing.
    pub misses: u64,
    /// Pages downloaded host→replica on warm-start.
    pub downloaded_pages: u64,
    /// Bytes downloaded host→replica on warm-start.
    pub downloaded_bytes: u64,
    /// Pages evicted by the capacity bound.
    pub evicted_pages: u64,
}

#[derive(Clone, Debug)]
struct StoreEntry {
    /// Page-aligned token prefix this entry holds.
    tokens: Vec<i32>,
    /// LRU stamp (larger = more recently used).
    stamp: u64,
}

/// The shared host-side prefix store (see module docs).
#[derive(Debug)]
pub struct HostPrefixStore {
    cfg: PrefixStoreConfig,
    entries: Vec<StoreEntry>,
    clock: u64,
    stats: PrefixStoreStats,
}

impl HostPrefixStore {
    /// An empty store with the given geometry.
    pub fn new(cfg: PrefixStoreConfig) -> Self {
        assert!(cfg.page_tokens > 0, "page_tokens must be positive");
        HostPrefixStore { cfg, entries: Vec::new(), clock: 0, stats: PrefixStoreStats::default() }
    }

    /// Transfer / hit counters so far.
    pub fn stats(&self) -> &PrefixStoreStats {
        &self.stats
    }

    /// Resident entries.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Resident pages across all entries.
    pub fn pages(&self) -> usize {
        self.entries.iter().map(|e| e.tokens.len() / self.cfg.page_tokens).sum()
    }

    /// Full pages `prompt` could contribute or consume.
    fn full_pages(&self, prompt: &[i32]) -> usize {
        prompt.len() / self.cfg.page_tokens
    }

    /// Best entry for `prompt`: `(index, covered_full_pages)` maximised
    /// over the common token prefix; ties go to the fresher entry.
    fn best(&self, prompt: &[i32]) -> Option<(usize, usize)> {
        self.entries
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let common =
                    e.tokens.iter().zip(prompt).take_while(|(a, b)| a == b).count();
                (i, common / self.cfg.page_tokens)
            })
            .max_by_key(|&(i, pages)| (pages, self.entries[i].stamp))
    }

    /// Routing probe: full pages of `prompt` the store holds (0 on
    /// miss).  A hit bumps the entry's LRU stamp; the caller follows a
    /// positive probe with `warm_prefix` on the target replica and
    /// books the transfer through [`HostPrefixStore::record_download`].
    pub fn probe(&mut self, prompt: &[i32]) -> usize {
        match self.best(prompt) {
            Some((idx, pages)) if pages > 0 => {
                self.clock += 1;
                self.entries[idx].stamp = self.clock;
                self.stats.hits += 1;
                pages
            }
            _ => {
                self.stats.misses += 1;
                0
            }
        }
    }

    /// Book `pages` downloaded host→replica (the pages a warm-start
    /// actually installed in the replica's retained pool).
    pub fn record_download(&mut self, pages: usize) {
        self.stats.downloaded_pages += pages as u64;
        self.stats.downloaded_bytes +=
            (pages * self.cfg.page_tokens * self.cfg.bytes_per_token) as u64;
    }

    /// Upload-on-miss after a completion: store `prompt`'s page-aligned
    /// prefix if not already resident.  A covered prefix only bumps the
    /// LRU; a clean extension of a resident prefix uploads just the
    /// missing tail pages; anything else becomes its own entry (host
    /// entries hold tokens, not device pages — overlap costs capacity,
    /// never correctness).  Evicts LRU entries past the capacity bound.
    pub fn offer(&mut self, prompt: &[i32]) {
        let n = self.full_pages(prompt);
        if n == 0 {
            return;
        }
        self.clock += 1;
        let tokens = &prompt[..n * self.cfg.page_tokens];
        match self.best(prompt) {
            Some((idx, covered)) if covered >= n => {
                self.entries[idx].stamp = self.clock;
            }
            Some((idx, covered))
                if covered > 0
                    && self.entries[idx].tokens.len()
                        == covered * self.cfg.page_tokens =>
            {
                self.entries[idx].tokens = tokens.to_vec();
                self.entries[idx].stamp = self.clock;
                self.count_upload(n - covered);
            }
            _ => {
                self.entries
                    .push(StoreEntry { tokens: tokens.to_vec(), stamp: self.clock });
                self.count_upload(n);
            }
        }
        self.evict_to_capacity();
    }

    fn count_upload(&mut self, pages: usize) {
        self.stats.uploads += 1;
        self.stats.uploaded_pages += pages as u64;
        self.stats.uploaded_bytes +=
            (pages * self.cfg.page_tokens * self.cfg.bytes_per_token) as u64;
    }

    /// Evict least-recently-used entries until the capacity bound
    /// holds.  A single entry larger than the whole bound stays — a
    /// store that evicted its only tenant would churn uploads forever.
    fn evict_to_capacity(&mut self) {
        while self.pages() > self.cfg.capacity_pages && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .expect("entries checked non-empty");
            let gone = self.entries.remove(victim);
            self.stats.evicted_pages +=
                (gone.tokens.len() / self.cfg.page_tokens) as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(capacity_pages: usize) -> HostPrefixStore {
        HostPrefixStore::new(PrefixStoreConfig {
            page_tokens: 4,
            capacity_pages,
            bytes_per_token: 10,
        })
    }

    #[test]
    fn upload_on_miss_dedups_and_extends() {
        let mut s = store(64);
        let prompt: Vec<i32> = (0..10).collect(); // 2 full pages + tail
        s.offer(&prompt);
        assert_eq!((s.entries(), s.pages()), (1, 2));
        assert_eq!(s.stats().uploaded_pages, 2);
        assert_eq!(s.stats().uploaded_bytes, 2 * 4 * 10);
        // resident prefix: no second upload
        s.offer(&prompt);
        assert_eq!(s.stats().uploaded_pages, 2);
        // clean extension uploads only the missing tail page
        let longer: Vec<i32> = (0..13).collect(); // 3 full pages
        s.offer(&longer);
        assert_eq!((s.entries(), s.pages()), (1, 3));
        assert_eq!(s.stats().uploaded_pages, 3);
        // divergent prompt becomes its own entry
        let other: Vec<i32> = (100..108).collect();
        s.offer(&other);
        assert_eq!((s.entries(), s.pages()), (2, 5));
        // sub-page prompts contribute nothing
        s.offer(&[1, 2, 3]);
        assert_eq!(s.entries(), 2);
    }

    #[test]
    fn probe_reports_coverage_and_counts_hits() {
        let mut s = store(64);
        assert_eq!(s.probe(&[1, 2, 3, 4]), 0);
        assert_eq!(s.stats().misses, 1);
        let prompt: Vec<i32> = (0..8).collect();
        s.offer(&prompt);
        // identical prompt: both pages covered
        assert_eq!(s.probe(&prompt), 2);
        // shared first page only
        assert_eq!(s.probe(&[0, 1, 2, 3, 9, 9, 9, 9]), 1);
        assert_eq!(s.stats().hits, 2);
        s.record_download(2);
        assert_eq!(s.stats().downloaded_pages, 2);
        assert_eq!(s.stats().downloaded_bytes, 2 * 4 * 10);
    }

    #[test]
    fn capacity_evicts_lru_entries() {
        let mut s = store(4);
        let a: Vec<i32> = (0..8).collect();
        let b: Vec<i32> = (100..108).collect();
        s.offer(&a);
        s.offer(&b);
        assert_eq!(s.pages(), 4);
        // touch `a` so `b` is the LRU victim
        assert_eq!(s.probe(&a), 2);
        let c: Vec<i32> = (200..208).collect();
        s.offer(&c);
        assert!(s.pages() <= 4);
        assert_eq!(s.stats().evicted_pages, 2);
        assert_eq!(s.probe(&a), 2, "recently-used entry survived");
        assert_eq!(s.probe(&b), 0, "LRU entry evicted");
        // a lone oversized tenant is kept, not churned
        let mut s = store(1);
        s.offer(&a);
        assert_eq!((s.entries(), s.pages()), (1, 2));
    }
}
