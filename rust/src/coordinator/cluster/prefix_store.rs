//! Shared host-side prefix store backing the per-replica retained
//! prefix pools.
//!
//! One replica's retained pool dies with it; a shared system prompt
//! re-routed after a replica death would otherwise prefill from
//! scratch on its new home.  The store keeps the *page-aligned token
//! prefixes* of completed prompts host-side: a completion uploads its
//! prefix on miss, and routing probes the store so the target replica
//! can warm-start the prefix into its own retained pool
//! ([`crate::coordinator::frontend::ServingEngine::warm_prefix_kv`] →
//! `KvCacheManager::warm_prefix_host`) before the request is offered.
//!
//! Entries hold tokens always, and — when the completing replica has a
//! host KV tier to stage them in — the actual KV page bytes
//! ([`PrefixKv`], via `ServingEngine::export_prefix`).  On the
//! simulator tokens are the whole truth (sim tokens are a pure
//! function of seed and prompt); on the real engine the payload is
//! what turns a warm-start from a logical reservation into a device
//! upload of previously computed KV.
//!
//! The stats keep those two worlds apart: *logical* counters (offers,
//! probe hits, pages stored or warm-started) track bookkeeping events
//! that move no KV, while *transfer* counters (uploads/downloads with
//! their page and byte totals) count only real payload bytes crossing
//! the store — the same discipline as the runtime's `TransferTotals`.
//! Like the device pools, the store is bounded and LRU-evicted.

use crate::coordinator::kvcache::host_tier::PrefixKv;

/// Host prefix store geometry config.
#[derive(Clone, Copy, Debug)]
pub struct PrefixStoreConfig {
    /// Tokens per stored page — match the replicas' KV page size so
    /// warm-started pages line up with the device pools.
    pub page_tokens: usize,
    /// Resident-page bound; least-recently-used entries evict past it.
    pub capacity_pages: usize,
}

impl Default for PrefixStoreConfig {
    fn default() -> Self {
        PrefixStoreConfig { page_tokens: 16, capacity_pages: 256 }
    }
}

/// Monotonic counters for the store, split into *logical* bookkeeping
/// events (no KV bytes move) and *byte-moving transfers* (real payload
/// bytes crossing the store boundary).  Conflating the two was a bug:
/// a token-only warm-start on the simulator used to book priced
/// "bytes" that no hardware ever moved.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStoreStats {
    // -- logical events: bookkeeping only --
    /// Completions offered to the store (≥ 1 full page).
    pub offers: u64,
    /// Token pages added to the store by offers.
    pub stored_pages: u64,
    /// Routing probes that found a stored prefix.
    pub hits: u64,
    /// Routing probes that found nothing.
    pub misses: u64,
    /// Pages warm-started into replica pools (logical reservation;
    /// payload-backed or not).
    pub warmed_pages: u64,
    /// Token pages evicted by the capacity bound.
    pub evicted_pages: u64,
    // -- byte-moving transfers: real KV payload only --
    /// Payload uploads (completions that attached KV bytes).
    pub uploads: u64,
    /// KV pages uploaded replica→store.
    pub uploaded_pages: u64,
    /// KV bytes uploaded replica→store (actual payload length).
    pub uploaded_bytes: u64,
    /// Payload downloads (warm-starts that shipped KV bytes).
    pub downloads: u64,
    /// KV pages downloaded store→replica on payload-backed warm-starts.
    pub downloaded_pages: u64,
    /// KV bytes downloaded store→replica (actual payload length).
    pub downloaded_bytes: u64,
}

#[derive(Clone, Debug)]
struct StoreEntry {
    /// Page-aligned token prefix this entry holds.
    tokens: Vec<i32>,
    /// Real KV page bytes for a (possibly shorter) prefix of `tokens`,
    /// when the completing replica could export them.
    kv: Option<PrefixKv>,
    /// LRU stamp (larger = more recently used).
    stamp: u64,
}

/// The shared host-side prefix store (see module docs).
#[derive(Debug)]
pub struct HostPrefixStore {
    cfg: PrefixStoreConfig,
    entries: Vec<StoreEntry>,
    clock: u64,
    stats: PrefixStoreStats,
}

impl HostPrefixStore {
    /// An empty store with the given geometry.
    pub fn new(cfg: PrefixStoreConfig) -> Self {
        assert!(cfg.page_tokens > 0, "page_tokens must be positive");
        HostPrefixStore { cfg, entries: Vec::new(), clock: 0, stats: PrefixStoreStats::default() }
    }

    /// Logical / transfer counters so far.
    pub fn stats(&self) -> &PrefixStoreStats {
        &self.stats
    }

    /// Resident entries.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Resident token pages across all entries.
    pub fn pages(&self) -> usize {
        self.entries.iter().map(|e| e.tokens.len() / self.cfg.page_tokens).sum()
    }

    /// Full pages `prompt` could contribute or consume.
    fn full_pages(&self, prompt: &[i32]) -> usize {
        prompt.len() / self.cfg.page_tokens
    }

    /// Best entry for `prompt`: `(index, covered_full_pages)` maximised
    /// over the common token prefix; ties go to the fresher entry.
    fn best(&self, prompt: &[i32]) -> Option<(usize, usize)> {
        self.entries
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let common =
                    e.tokens.iter().zip(prompt).take_while(|(a, b)| a == b).count();
                (i, common / self.cfg.page_tokens)
            })
            .max_by_key(|&(i, pages)| (pages, self.entries[i].stamp))
    }

    /// Routing probe: full pages of `prompt` the store holds (0 on
    /// miss).  A hit bumps the entry's LRU stamp; the caller follows a
    /// positive probe with a warm-start on the target replica and
    /// books it through [`HostPrefixStore::record_warm`] (plus
    /// [`HostPrefixStore::record_download`] when payload bytes moved).
    pub fn probe(&mut self, prompt: &[i32]) -> usize {
        match self.best(prompt) {
            Some((idx, pages)) if pages > 0 => {
                self.clock += 1;
                self.entries[idx].stamp = self.clock;
                self.stats.hits += 1;
                pages
            }
            _ => {
                self.stats.misses += 1;
                0
            }
        }
    }

    /// The deepest stored KV payload usable for `prompt`: its tokens
    /// must be an *exact* prefix of the prompt (a replica warms the
    /// prompt's own tokens against the payload's bytes, so a divergent
    /// payload would serve another prompt's KV as this one's).
    pub fn payload_for(&self, prompt: &[i32]) -> Option<PrefixKv> {
        self.entries
            .iter()
            .filter_map(|e| e.kv.as_ref())
            .filter(|kv| {
                prompt.len() >= kv.tokens.len()
                    && prompt[..kv.tokens.len()] == kv.tokens[..]
            })
            .max_by_key(|kv| kv.pages)
            .cloned()
    }

    /// Book `pages` logically warm-started into a replica's retained
    /// pool (no bytes implied — pair with
    /// [`HostPrefixStore::record_download`] when payload moved).
    pub fn record_warm(&mut self, pages: usize) {
        self.stats.warmed_pages += pages as u64;
    }

    /// Book one payload download: `pages` installed on the replica from
    /// `bytes` of real KV shipped store→replica.
    pub fn record_download(&mut self, pages: usize, bytes: usize) {
        if pages == 0 && bytes == 0 {
            return;
        }
        self.stats.downloads += 1;
        self.stats.downloaded_pages += pages as u64;
        self.stats.downloaded_bytes += bytes as u64;
    }

    /// Token-only [`HostPrefixStore::offer_with_payload`].
    pub fn offer(&mut self, prompt: &[i32]) {
        self.offer_with_payload(prompt, None);
    }

    /// Upload-on-miss after a completion: store `prompt`'s page-aligned
    /// prefix if not already resident.  A covered prefix only bumps the
    /// LRU; a clean extension of a resident prefix stores just the
    /// missing tail pages; anything else becomes its own entry (host
    /// entries hold tokens, not device pages — overlap costs capacity,
    /// never correctness).  A payload with real bytes whose tokens
    /// page-align and prefix the prompt attaches to the entry when it
    /// deepens the entry's KV coverage — only then do the transfer
    /// counters move.  Evicts LRU entries past the capacity bound.
    pub fn offer_with_payload(&mut self, prompt: &[i32], payload: Option<PrefixKv>) {
        let n = self.full_pages(prompt);
        if n == 0 {
            return;
        }
        self.clock += 1;
        self.stats.offers += 1;
        let payload = payload.filter(|kv| {
            kv.pages > 0
                && kv.bytes.is_some()
                && kv.tokens.len() == kv.pages * self.cfg.page_tokens
                && kv.pages <= n
                && prompt[..kv.tokens.len()] == kv.tokens[..]
        });
        let tokens = &prompt[..n * self.cfg.page_tokens];
        match self.best(prompt) {
            Some((idx, covered)) if covered >= n => {
                self.entries[idx].stamp = self.clock;
                self.attach(idx, payload);
            }
            Some((idx, covered))
                if covered > 0
                    && self.entries[idx].tokens.len()
                        == covered * self.cfg.page_tokens =>
            {
                self.entries[idx].tokens = tokens.to_vec();
                self.entries[idx].stamp = self.clock;
                self.stats.stored_pages += (n - covered) as u64;
                self.attach(idx, payload);
            }
            _ => {
                self.entries.push(StoreEntry {
                    tokens: tokens.to_vec(),
                    kv: None,
                    stamp: self.clock,
                });
                self.stats.stored_pages += n as u64;
                self.attach(self.entries.len() - 1, payload);
            }
        }
        self.evict_to_capacity();
    }

    /// Attach `payload` to entry `idx` when it deepens the entry's KV
    /// coverage, booking the actual bytes as an upload.  A shallower
    /// payload never downgrades a deeper stored one.
    fn attach(&mut self, idx: usize, payload: Option<PrefixKv>) {
        let Some(kv) = payload else { return };
        let have = self.entries[idx].kv.as_ref().map_or(0, |k| k.pages);
        if kv.pages <= have {
            return;
        }
        self.stats.uploads += 1;
        self.stats.uploaded_pages += kv.pages as u64;
        self.stats.uploaded_bytes += kv.bytes.as_ref().map_or(0, |b| b.len()) as u64;
        self.entries[idx].kv = Some(kv);
    }

    /// Evict least-recently-used entries until the capacity bound
    /// holds (an evicted entry's payload dies with it).  A single entry
    /// larger than the whole bound stays — a store that evicted its
    /// only tenant would churn uploads forever.
    fn evict_to_capacity(&mut self) {
        while self.pages() > self.cfg.capacity_pages && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(i, _)| i)
                .expect("entries checked non-empty");
            let gone = self.entries.remove(victim);
            self.stats.evicted_pages +=
                (gone.tokens.len() / self.cfg.page_tokens) as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(capacity_pages: usize) -> HostPrefixStore {
        HostPrefixStore::new(PrefixStoreConfig { page_tokens: 4, capacity_pages })
    }

    fn kv(upto: i32, pages: usize, fill: u8) -> PrefixKv {
        PrefixKv {
            tokens: (0..upto).collect(),
            pages,
            bytes: Some(vec![fill; pages * 64]),
        }
    }

    #[test]
    fn offer_on_miss_dedups_and_extends() {
        let mut s = store(64);
        let prompt: Vec<i32> = (0..10).collect(); // 2 full pages + tail
        s.offer(&prompt);
        assert_eq!((s.entries(), s.pages()), (1, 2));
        assert_eq!((s.stats().offers, s.stats().stored_pages), (1, 2));
        // token-only offers move no KV bytes — logical counters only
        assert_eq!((s.stats().uploads, s.stats().uploaded_bytes), (0, 0));
        // resident prefix: no second store
        s.offer(&prompt);
        assert_eq!(s.stats().stored_pages, 2);
        // clean extension stores only the missing tail page
        let longer: Vec<i32> = (0..13).collect(); // 3 full pages
        s.offer(&longer);
        assert_eq!((s.entries(), s.pages()), (1, 3));
        assert_eq!(s.stats().stored_pages, 3);
        // divergent prompt becomes its own entry
        let other: Vec<i32> = (100..108).collect();
        s.offer(&other);
        assert_eq!((s.entries(), s.pages()), (2, 5));
        // sub-page prompts contribute nothing
        s.offer(&[1, 2, 3]);
        assert_eq!(s.entries(), 2);
        assert_eq!(s.stats().offers, 4);
    }

    #[test]
    fn probe_reports_coverage_and_counts_hits() {
        let mut s = store(64);
        assert_eq!(s.probe(&[1, 2, 3, 4]), 0);
        assert_eq!(s.stats().misses, 1);
        let prompt: Vec<i32> = (0..8).collect();
        s.offer(&prompt);
        // identical prompt: both pages covered
        assert_eq!(s.probe(&prompt), 2);
        // shared first page only
        assert_eq!(s.probe(&[0, 1, 2, 3, 9, 9, 9, 9]), 1);
        assert_eq!(s.stats().hits, 2);
        // a logical warm books no transfer …
        s.record_warm(2);
        assert_eq!(s.stats().warmed_pages, 2);
        assert_eq!((s.stats().downloads, s.stats().downloaded_bytes), (0, 0));
        // … a payload download books the actual bytes that moved
        s.record_download(2, 512);
        assert_eq!(s.stats().downloads, 1);
        assert_eq!(s.stats().downloaded_pages, 2);
        assert_eq!(s.stats().downloaded_bytes, 512);
    }

    #[test]
    fn payload_attaches_upgrades_and_gates_on_prompt() {
        let mut s = store(64);
        let prompt: Vec<i32> = (0..12).collect(); // 3 full pages
        // divergent payload tokens never attach (they would serve
        // another prompt's KV as this one's)
        s.offer_with_payload(
            &prompt,
            Some(PrefixKv { tokens: vec![9; 4], pages: 1, bytes: Some(vec![0; 64]) }),
        );
        assert_eq!(s.stats().uploads, 0);
        assert!(s.payload_for(&prompt).is_none());
        // a genuine 2-page payload attaches and counts its real bytes
        let two = kv(8, 2, 7);
        s.offer_with_payload(&prompt, Some(two.clone()));
        assert_eq!(
            (s.stats().uploads, s.stats().uploaded_pages, s.stats().uploaded_bytes),
            (1, 2, 128)
        );
        assert_eq!(s.payload_for(&prompt), Some(two.clone()));
        // a shallower payload never downgrades the stored one
        s.offer_with_payload(&prompt, Some(kv(4, 1, 1)));
        assert_eq!(s.stats().uploads, 1);
        assert_eq!(s.payload_for(&prompt), Some(two));
        // a deeper payload upgrades and books only its own bytes
        let three = kv(12, 3, 8);
        s.offer_with_payload(&prompt, Some(three.clone()));
        assert_eq!((s.stats().uploads, s.stats().uploaded_bytes), (2, 128 + 192));
        // fetch gates on the *requesting* prompt, not mere residency
        let extended: Vec<i32> = (0..20).collect();
        assert_eq!(s.payload_for(&extended), Some(three));
        assert!(s.payload_for(&[0, 1, 9, 9]).is_none());
        assert!(s.payload_for(&prompt[..8]).is_none(), "payload deeper than prompt");
        // a payload without bytes is logical-only and never attaches
        let mut s2 = store(64);
        s2.offer_with_payload(
            &prompt,
            Some(PrefixKv { tokens: (0..8).collect(), pages: 2, bytes: None }),
        );
        assert_eq!(s2.stats().uploads, 0);
        assert!(s2.payload_for(&prompt).is_none());
    }

    #[test]
    fn capacity_evicts_lru_entries() {
        let mut s = store(4);
        let a: Vec<i32> = (0..8).collect();
        let b: Vec<i32> = (100..108).collect();
        s.offer(&a);
        s.offer(&b);
        assert_eq!(s.pages(), 4);
        // touch `a` so `b` is the LRU victim
        assert_eq!(s.probe(&a), 2);
        let c: Vec<i32> = (200..208).collect();
        s.offer(&c);
        assert!(s.pages() <= 4);
        assert_eq!(s.stats().evicted_pages, 2);
        assert_eq!(s.probe(&a), 2, "recently-used entry survived");
        assert_eq!(s.probe(&b), 0, "LRU entry evicted");
        // a lone oversized tenant is kept, not churned
        let mut s = store(1);
        s.offer(&a);
        assert_eq!((s.entries(), s.pages()), (1, 2));
    }
}
