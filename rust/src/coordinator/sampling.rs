//! Per-request token sampling (greedy / temperature / top-k).
//!
//! Pure policy over one logits row — no engine state: each decode slot
//! carries its own [`SamplingParams`] and private [`Rng`] stream, so a
//! request's generation never depends on which other slots are in
//! flight (the slot-isolation property the integration tests pin).

use crate::coordinator::request::SamplingParams;
use crate::rng::Rng;

/// Sample a token id from one logits row per `params`:
/// * `temperature == 0` — greedy argmax (the serving default), fully
///   deterministic and rng-free;
/// * otherwise — softmax at `temperature` over the `top_k` highest
///   logits (ties broken toward the lower index), drawn from `rng`.
pub fn sample_logits(row: &[f32], params: &SamplingParams, rng: &mut Rng) -> i32 {
    debug_assert!(!row.is_empty());
    if params.temperature <= 0.0 {
        let mut best = 0usize;
        let mut bestv = f32::NEG_INFINITY;
        for (i, &x) in row.iter().enumerate() {
            if x > bestv {
                bestv = x;
                best = i;
            }
        }
        return best as i32;
    }
    // candidate set: indices sorted by logit desc (stable on ties);
    // O(V log V) selection is fine at serving vocab sizes
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| {
        row[b]
            .partial_cmp(&row[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let k = params.top_k.unwrap_or(row.len()).clamp(1, row.len());
    idx.truncate(k);
    let max = row[idx[0]];
    let weights: Vec<f32> = idx
        .iter()
        .map(|&i| ((row[i] - max) / params.temperature).exp())
        .collect();
    idx[rng.categorical(&weights)] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax_and_deterministic() {
        let row = [0.1f32, 2.5, -1.0, 2.4];
        let params = SamplingParams::default(); // temperature 0
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            assert_eq!(sample_logits(&row, &params, &mut rng), 1);
        }
    }

    #[test]
    fn temperature_with_top_k_1_is_argmax() {
        let row = [0.3f32, -0.2, 4.0, 1.0];
        let params = SamplingParams {
            temperature: 1.3,
            top_k: Some(1),
            ..Default::default()
        };
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            assert_eq!(sample_logits(&row, &params, &mut rng), 2);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        // flat logits: top_k=2 keeps the two lowest indices (stable ties)
        let row = [1.0f32; 6];
        let params = SamplingParams {
            temperature: 1.0,
            top_k: Some(2),
            ..Default::default()
        };
        let mut rng = Rng::new(11);
        let mut seen = [0usize; 6];
        for _ in 0..300 {
            seen[sample_logits(&row, &params, &mut rng) as usize] += 1;
        }
        assert!(seen[0] > 0 && seen[1] > 0, "{seen:?}");
        assert!(seen[2..].iter().all(|&c| c == 0), "{seen:?}");
    }

    #[test]
    fn sampling_is_reproducible_per_seed() {
        let row: Vec<f32> = (0..32).map(|i| ((i * 7) % 13) as f32 * 0.3).collect();
        let params = SamplingParams { temperature: 0.8, ..Default::default() };
        let draw = |seed: u64| -> Vec<i32> {
            let mut rng = Rng::new(seed);
            (0..20).map(|_| sample_logits(&row, &params, &mut rng)).collect()
        };
        assert_eq!(draw(3), draw(3));
        assert_ne!(draw(3), draw(4), "different streams should diverge");
    }

    #[test]
    fn nonzero_temperature_covers_more_than_argmax() {
        let row = [1.0f32, 1.1, 0.9, 1.05];
        let params = SamplingParams { temperature: 2.0, ..Default::default() };
        let mut rng = Rng::new(5);
        let distinct: std::collections::HashSet<i32> =
            (0..200).map(|_| sample_logits(&row, &params, &mut rng)).collect();
        assert!(distinct.len() > 1, "hot temperature must actually sample");
    }
}
