//! Per-expert routing load telemetry.
//!
//! The paper's performance story hinges on expert *imbalance*: padding-
//! based implementations waste memory and FLOPs proportional to how
//! unevenly the router spreads tokens (§1, §4.2).  This module makes that
//! observable at serving time: per-expert token counts, load coefficient
//! of variation, and the padding waste a block-padded implementation
//! *would* have incurred on the observed distribution.

/// Coefficient of variation of a count vector (0 = perfectly balanced).
///
/// Total-zero windows are a fact of life for the consumers of this
/// number — an empty decode step, a telemetry gap, a rebalancer window
/// that saw no traffic — and the naive `sd / mean` is NaN there, which
/// poisons every threshold comparison downstream (`NaN > t` is false,
/// `NaN < t` is false, and a NaN stored in a report breaks JSON).  The
/// guard lives here, once, so `ExpertStats::load_cv` and the mesh
/// rebalancer's sliding-window CV share it.
pub fn cv_of(counts: &[u64]) -> f64 {
    let n = counts.len() as f64;
    let total: u64 = counts.iter().sum();
    if n == 0.0 || total == 0 {
        return 0.0;
    }
    let mean = total as f64 / n;
    let var = counts.iter().map(|&c| (c as f64 - mean).powi(2)).sum::<f64>() / n;
    var.sqrt() / mean
}

/// Streaming per-expert load statistics.
#[derive(Clone, Debug)]
pub struct ExpertStats {
    counts: Vec<u64>,
    batches: u64,
}

impl ExpertStats {
    /// Zeroed counters for `num_experts` experts.
    pub fn new(num_experts: usize) -> Self {
        ExpertStats { counts: vec![0; num_experts], batches: 0 }
    }

    /// Number of experts tracked.
    pub fn num_experts(&self) -> usize {
        self.counts.len()
    }

    /// Record one routing decision batch: `assignments[i]` = expert id.
    pub fn record(&mut self, assignments: &[usize]) {
        for &e in assignments {
            if e < self.counts.len() {
                self.counts[e] += 1;
            }
        }
        self.batches += 1;
    }

    /// Record from pre-aggregated per-expert counts.
    pub fn record_counts(&mut self, counts: &[u64]) {
        for (c, &n) in self.counts.iter_mut().zip(counts) {
            *c += n;
        }
        self.batches += 1;
    }

    /// Total routed slots recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Per-expert totals.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Fraction of all routed slots handled by each expert.
    pub fn load_fractions(&self) -> Vec<f64> {
        let total = self.total().max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / total).collect()
    }

    /// Coefficient of variation of the per-expert load (0 = perfectly
    /// balanced; grows with imbalance).  Delegates to [`cv_of`], so the
    /// all-zero-window guard is shared with the mesh rebalancer.
    pub fn load_cv(&self) -> f64 {
        cv_of(&self.counts)
    }

    /// Padding waste ratio a Megablocks-style implementation would incur
    /// at block size `b` on the observed per-expert totals: padded_rows /
    /// actual_rows − 1.
    pub fn padding_waste(&self, b: u64) -> f64 {
        let total = self.total();
        if total == 0 {
            return 0.0;
        }
        let padded: u64 = self.counts.iter().map(|&c| c.div_ceil(b) * b).sum();
        padded as f64 / total as f64 - 1.0
    }

    /// Expert ids sorted by descending load (hot-expert report).
    pub fn hottest(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.counts.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(self.counts[i]));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_load_cv_zero() {
        let mut s = ExpertStats::new(4);
        s.record(&[0, 1, 2, 3, 0, 1, 2, 3]);
        assert!(s.load_cv() < 1e-9);
        assert_eq!(s.total(), 8);
    }

    #[test]
    fn imbalance_raises_cv() {
        let mut bal = ExpertStats::new(4);
        bal.record(&[0, 1, 2, 3]);
        let mut skew = ExpertStats::new(4);
        skew.record(&[0, 0, 0, 1]);
        assert!(skew.load_cv() > bal.load_cv());
    }

    #[test]
    fn padding_waste_zero_when_aligned() {
        let mut s = ExpertStats::new(2);
        s.record_counts(&[8, 16]);
        assert!(s.padding_waste(8) < 1e-9);
    }

    #[test]
    fn padding_waste_grows_with_fragmentation() {
        // 16 experts with 1 token each at block 8: padded 128 vs real 16
        let mut s = ExpertStats::new(16);
        s.record_counts(&[1; 16]);
        assert!((s.padding_waste(8) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn hottest_sorted() {
        let mut s = ExpertStats::new(3);
        s.record_counts(&[5, 20, 1]);
        assert_eq!(s.hottest(), vec![1, 0, 2]);
    }

    /// Regression: a window with zero routed tokens (empty decode step,
    /// telemetry gap) must report CV 0.0, never NaN — the rebalancer
    /// compares this against a threshold and NaN makes every comparison
    /// silently false.
    #[test]
    fn all_zero_window_cv_is_zero_not_nan() {
        assert_eq!(cv_of(&[]), 0.0);
        assert_eq!(cv_of(&[0, 0, 0, 0]), 0.0);
        assert!(!cv_of(&[0, 0]).is_nan());
        let s = ExpertStats::new(8);
        assert_eq!(s.load_cv(), 0.0, "fresh stats are balanced, not NaN");
        let mut gap = ExpertStats::new(8);
        gap.record_counts(&[0; 8]); // a recorded-but-empty batch
        assert_eq!(gap.load_cv(), 0.0);
    }

    #[test]
    fn cv_of_matches_hand_value() {
        // [3, 1]: mean 2, sd 1 → CV 0.5
        assert!((cv_of(&[3, 1]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fractions_sum_to_one() {
        let mut s = ExpertStats::new(5);
        s.record_counts(&[3, 9, 1, 0, 7]);
        let sum: f64 = s.load_fractions().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }
}
