//! Training driver: drives the AOT `*_train_*` artifacts from Rust.
//!
//! Owns the flattened `(params, m, v)` optimizer state, generates token
//! batches from the synthetic corpus, and executes the compiled train
//! step — Python never runs.  Supports both single-step artifacts
//! (`lm_*_train_<impl>`) and scan-chunked ones
//! (`lm_*_train_chunk_<impl>`, several optimizer steps per call).
//!
//! ## Device-resident state
//!
//! By default ([`StatePlacement::Device`]) the state lives as
//! `xla::PjRtBuffer`s chained output→input across steps through
//! [`Runtime::run_chain_step`], driven by the `chain_map` the train
//! artifacts declare in the manifest.  A steady-state step stages only
//! the step counter and the token batch up and downloads only the loss
//! — host traffic is O(batch tokens), independent of the parameter
//! count.  The pre-chaining behaviour (every step ships the whole
//! `3 × n_params` state through host literals both ways) is kept as
//! [`StatePlacement::Host`]: it is the equivalence baseline for tests,
//! the bytes-per-step "before" measured by the fig-4a bench, and the
//! automatic fallback when an artifact dir predates the `chain_map`
//! contract.  Parameters leave the device only on demand
//! ([`Trainer::params_tensors`] — the checkpoint/eval boundary).

use anyhow::{bail, Context, Result};

use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::tokenizer::SyntheticCorpus;

/// Where the flattened `(params ++ m ++ v)` optimizer state lives
/// between [`Trainer::step`] calls.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatePlacement {
    /// Device buffers chained output→input (the default): steady-state
    /// host traffic is the step counter + token batch up, loss down.
    Device,
    /// Host literals re-uploaded every call (pre-chaining behaviour):
    /// kept as the equivalence/bytes-per-step baseline and as the
    /// fallback for artifact dirs without a `chain_map`.
    Host,
}

/// The state tuple in its placement-specific representation.
enum TrainState {
    Device(Vec<xla::PjRtBuffer>),
    Host(Vec<xla::Literal>),
}

/// One training run's progress record.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    /// Mean cross-entropy per artifact call.
    pub losses: Vec<f32>,
    /// Total tokens consumed.
    pub tokens_seen: u64,
    /// Wall-clock duration of the run.
    pub wall_secs: f64,
}

impl TrainLog {
    /// Training throughput over the whole run.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.tokens_seen as f64 / self.wall_secs
        }
    }
}

/// Driver around one `lm_*_train[_chunk]_*` artifact.
pub struct Trainer {
    runtime: std::sync::Arc<Runtime>,
    artifact: String,
    /// (params ++ m ++ v) in manifest order, placement-dependent
    state: TrainState,
    n_params: usize,
    batch: usize,
    seq_plus1: usize,
    chunk_steps: usize,
    step: i32,
    corpus: SyntheticCorpus,
    vocab: usize,
}

impl Trainer {
    /// Initialise from `<prefix>_init` + the given train artifact with
    /// the default [`StatePlacement::Device`].
    pub fn new(
        runtime: std::sync::Arc<Runtime>, init_artifact: &str, train_artifact: &str,
        seed: u64,
    ) -> Result<Trainer> {
        Self::new_with_placement(
            runtime,
            init_artifact,
            train_artifact,
            seed,
            StatePlacement::Device,
        )
    }

    /// [`Self::new`] with an explicit state placement.  Requesting
    /// [`StatePlacement::Device`] against an artifact dir that predates
    /// the `chain_map` contract falls back to host literals (with a
    /// warning) rather than failing; an *invalid* declared map is a
    /// hard error.
    pub fn new_with_placement(
        runtime: std::sync::Arc<Runtime>, init_artifact: &str, train_artifact: &str,
        seed: u64, placement: StatePlacement,
    ) -> Result<Trainer> {
        let spec = runtime.spec(train_artifact)?.clone();
        let names = spec
            .param_names()
            .context("train artifact missing param_names meta")?;
        let n_params = names.len();
        let kind = spec.meta_str("kind").unwrap_or("");
        let chunk_steps = if kind == "lm_train_chunk" {
            spec.meta_usize("chunk_steps").unwrap_or(1)
        } else {
            1
        };
        // tokens input: single-step (B, S+1); chunked (C, B, S+1)
        let tok_spec = &spec.inputs[1];
        let (batch, seq_plus1) = if chunk_steps > 1 {
            (tok_spec.shape[1], tok_spec.shape[2])
        } else {
            (tok_spec.shape[0], tok_spec.shape[1])
        };
        let vocab = spec.meta_usize("vocab_size").context("vocab_size meta")?;

        // params from the init artifact; optimizer state starts at zero
        let params_t = runtime
            .run(init_artifact, &[Tensor::scalar_u32(seed as u32)])
            .context("running init artifact")?;
        if params_t.len() != n_params {
            bail!(
                "init artifact returned {} tensors, manifest lists {n_params} params",
                params_t.len()
            );
        }
        let zeros: Vec<Tensor> = params_t
            .iter()
            .map(|t| Tensor::zeros(t.dtype, &t.shape))
            .collect();
        let mut host = params_t;
        host.extend(zeros.iter().cloned()); // m
        host.extend(zeros); // v

        let effective = match placement {
            StatePlacement::Device if !spec.has_chain_map() => {
                // stderr, not just log: no logger is installed in the
                // binaries/benches and a silent fallback would let the
                // bytes-per-step reports claim a device path that never ran
                eprintln!(
                    "WARNING: train artifact '{train_artifact}' declares no \
                     chain_map — falling back to host-literal state (re-run \
                     `make artifacts` for device-resident training)"
                );
                StatePlacement::Host
            }
            StatePlacement::Device => {
                // the Trainer rebuilds every call as [step, tokens] ++ state,
                // so the declared contract must be *exactly* loss → host,
                // output j → input j+1 — a shifted or permuted map over the
                // same-shaped state tensors would bind buffers to the wrong
                // inputs with no runtime error otherwise
                let map = spec.checked_chain_map()?;
                let want: Vec<Option<usize>> = std::iter::once(None)
                    .chain((0..3 * n_params).map(|i| Some(2 + i)))
                    .collect();
                if map != want {
                    bail!(
                        "train artifact '{train_artifact}' chain_map does not \
                         match the trainer contract (loss -> host, output j -> \
                         input j+1): got {map:?}"
                    );
                }
                StatePlacement::Device
            }
            StatePlacement::Host => StatePlacement::Host,
        };
        let state = match effective {
            StatePlacement::Device => TrainState::Device(
                // one-time staging, accounted against the init artifact
                // (mirrors the serving engine's param upload)
                host.iter()
                    .map(|t| runtime.upload_tensor_for(init_artifact, t))
                    .collect::<Result<_>>()?,
            ),
            StatePlacement::Host => TrainState::Host(
                host.iter().map(Tensor::to_literal).collect::<Result<_>>()?,
            ),
        };
        Ok(Trainer {
            runtime,
            artifact: train_artifact.to_string(),
            state,
            n_params,
            batch,
            seq_plus1,
            chunk_steps,
            step: 1,
            corpus: SyntheticCorpus::new(vocab, seed ^ 0xC0 | 1),
            vocab,
        })
    }

    /// Where the optimizer state actually lives (the requested placement
    /// may have fallen back — see [`Self::new_with_placement`]).
    pub fn placement(&self) -> StatePlacement {
        match self.state {
            TrainState::Device(_) => StatePlacement::Device,
            TrainState::Host(_) => StatePlacement::Host,
        }
    }

    /// Tokens consumed per artifact call.
    pub fn batch_tokens(&self) -> usize {
        self.batch * (self.seq_plus1 - 1) * self.chunk_steps
    }

    /// Optimizer steps per artifact call (1 for single-step artifacts).
    pub fn chunk_steps(&self) -> usize {
        self.chunk_steps
    }

    /// Model vocabulary size.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Host-side size of one full `(params ++ m ++ v)` state copy in
    /// bytes — the per-step traffic the device-resident path avoids.
    pub fn state_bytes(&self) -> usize {
        let spec = match self.runtime.spec(&self.artifact) {
            Ok(s) => s,
            Err(_) => return 0,
        };
        spec.inputs[2..].iter().map(|io| io.size_bytes()).sum()
    }

    /// Sample the next token batch from the corpus.
    fn next_batch(&mut self) -> Result<Tensor> {
        if self.chunk_steps > 1 {
            let data = self
                .corpus
                .sample_batch(self.chunk_steps * self.batch, self.seq_plus1);
            Tensor::from_i32(
                &[self.chunk_steps, self.batch, self.seq_plus1], data,
            )
        } else {
            let data = self.corpus.sample_batch(self.batch, self.seq_plus1);
            Tensor::from_i32(&[self.batch, self.seq_plus1], data)
        }
    }

    /// Run one artifact call (1 or `chunk_steps` optimizer steps).
    /// Returns the mean cross-entropy of the call.
    pub fn step(&mut self) -> Result<f32> {
        let tokens = self.next_batch()?;
        let n_state = 3 * self.n_params;
        let (loss_t, new_state) = match &self.state {
            TrainState::Host(lits) => {
                let step_l = Tensor::scalar_i32(self.step).to_literal()?;
                let tok_l = tokens.to_literal()?;
                let mut args: Vec<&xla::Literal> =
                    Vec::with_capacity(2 + lits.len());
                args.push(&step_l);
                args.push(&tok_l);
                for s in lits {
                    args.push(s);
                }
                let mut outs = self.runtime.run_literals(&self.artifact, &args)?;
                // outs: [loss(es), params.., m.., v..]
                if outs.len() != 1 + n_state {
                    bail!(
                        "train artifact returned {} outputs, want {}",
                        outs.len(),
                        1 + n_state
                    );
                }
                let new_state: Vec<xla::Literal> = outs.split_off(1);
                let loss = Tensor::from_literal(&outs[0])?;
                (loss, TrainState::Host(new_state))
            }
            TrainState::Device(bufs) => {
                // steady-state host traffic: the step scalar + token
                // batch up, the loss down — the state tuple stays on
                // device, chained by the artifact's manifest chain_map
                let step_b = self
                    .runtime
                    .upload_tensor_for(&self.artifact, &Tensor::scalar_i32(self.step))?;
                let tok_b = self.runtime.upload_tensor_for(&self.artifact, &tokens)?;
                let mut args: Vec<&xla::PjRtBuffer> =
                    Vec::with_capacity(2 + bufs.len());
                args.push(&step_b);
                args.push(&tok_b);
                for b in bufs {
                    args.push(b);
                }
                let mut chain = self.runtime.run_chain_step(&self.artifact, &args)?;
                if chain.state.len() != n_state || chain.host.len() != 1 {
                    bail!(
                        "train artifact chained {} outputs / {} host, want {n_state} / 1",
                        chain.state.len(),
                        chain.host.len()
                    );
                }
                let loss = chain.host.pop().unwrap();
                (loss, TrainState::Device(chain.state))
            }
        };
        self.state = new_state;
        self.step += self.chunk_steps as i32;
        loss_t.mean()
    }

    /// Train for `calls` artifact calls, logging every `log_every`.
    pub fn run(&mut self, calls: usize, log_every: usize) -> Result<TrainLog> {
        let mut log = TrainLog::default();
        let t0 = std::time::Instant::now();
        for c in 0..calls {
            let loss = self.step()?;
            log.losses.push(loss);
            log.tokens_seen += self.batch_tokens() as u64;
            if log_every > 0 && (c + 1) % log_every == 0 {
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "step {:>5}  loss {:.4}  ({:.1} tok/s)",
                    self.step - 1,
                    loss,
                    log.tokens_seen as f64 / dt
                );
            }
        }
        log.wall_secs = t0.elapsed().as_secs_f64();
        Ok(log)
    }

    /// Current flattened parameters, downloaded on demand (the
    /// checkpoint/eval boundary — the only point device-resident state
    /// crosses back to host, accounted against the train artifact).
    pub fn params_tensors(&self) -> Result<Vec<Tensor>> {
        match &self.state {
            TrainState::Host(lits) => lits[..self.n_params]
                .iter()
                .map(Tensor::from_literal)
                .collect(),
            TrainState::Device(bufs) => bufs[..self.n_params]
                .iter()
                .map(|b| self.runtime.download_for(&self.artifact, b))
                .collect(),
        }
    }

    /// Corpus conditional entropy (nats) — the loss floor for reporting.
    pub fn loss_floor(&self) -> f64 {
        self.corpus.conditional_entropy()
    }
}
