//! Training driver: drives the AOT `*_train_*` artifacts from Rust.
//!
//! Owns the flattened (params, m, v) optimizer state as XLA literals,
//! generates token batches from the synthetic corpus, and executes the
//! compiled train step — Python never runs.  Supports both single-step
//! artifacts (`lm_*_train_<impl>`) and scan-chunked ones
//! (`lm_*_train_chunk_<impl>`, several optimizer steps per call, which
//! amortises the host round-trip the `xla` crate's tuple outputs force).

use anyhow::{bail, Context, Result};

use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::tokenizer::SyntheticCorpus;

/// One training run's progress record.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub tokens_seen: u64,
    pub wall_secs: f64,
}

impl TrainLog {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.tokens_seen as f64 / self.wall_secs
        }
    }
}

/// Driver around one `lm_*_train[_chunk]_*` artifact.
pub struct Trainer {
    runtime: std::sync::Arc<Runtime>,
    artifact: String,
    /// (params ++ m ++ v) as literals, in manifest order
    state: Vec<xla::Literal>,
    n_params: usize,
    batch: usize,
    seq_plus1: usize,
    chunk_steps: usize,
    step: i32,
    corpus: SyntheticCorpus,
    vocab: usize,
}

impl Trainer {
    /// Initialise from `<prefix>_init` + the given train artifact.
    pub fn new(
        runtime: std::sync::Arc<Runtime>, init_artifact: &str, train_artifact: &str,
        seed: u64,
    ) -> Result<Trainer> {
        let spec = runtime.spec(train_artifact)?.clone();
        let names = spec
            .param_names()
            .context("train artifact missing param_names meta")?;
        let n_params = names.len();
        let kind = spec.meta_str("kind").unwrap_or("");
        let chunk_steps = if kind == "lm_train_chunk" {
            spec.meta_usize("chunk_steps").unwrap_or(1)
        } else {
            1
        };
        // tokens input: single-step (B, S+1); chunked (C, B, S+1)
        let tok_spec = &spec.inputs[1];
        let (batch, seq_plus1) = if chunk_steps > 1 {
            (tok_spec.shape[1], tok_spec.shape[2])
        } else {
            (tok_spec.shape[0], tok_spec.shape[1])
        };
        let vocab = spec.meta_usize("vocab_size").context("vocab_size meta")?;

        // params from the init artifact; optimizer state starts at zero
        let params_t = runtime
            .run(init_artifact, &[Tensor::scalar_u32(seed as u32)])
            .context("running init artifact")?;
        if params_t.len() != n_params {
            bail!(
                "init artifact returned {} tensors, manifest lists {n_params} params",
                params_t.len()
            );
        }
        let mut state = runtime.to_literals(&params_t)?;
        for t in &params_t {
            state.push(Tensor::zeros(t.dtype, &t.shape).to_literal()?); // m
        }
        for t in &params_t {
            state.push(Tensor::zeros(t.dtype, &t.shape).to_literal()?); // v
        }
        Ok(Trainer {
            runtime,
            artifact: train_artifact.to_string(),
            state,
            n_params,
            batch,
            seq_plus1,
            chunk_steps,
            step: 1,
            corpus: SyntheticCorpus::new(vocab, seed ^ 0xC0 | 1),
            vocab,
        })
    }

    pub fn batch_tokens(&self) -> usize {
        self.batch * (self.seq_plus1 - 1) * self.chunk_steps
    }

    pub fn chunk_steps(&self) -> usize {
        self.chunk_steps
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Sample the next token batch from the corpus.
    fn next_batch(&mut self) -> Result<Tensor> {
        if self.chunk_steps > 1 {
            let data = self
                .corpus
                .sample_batch(self.chunk_steps * self.batch, self.seq_plus1);
            Tensor::from_i32(
                &[self.chunk_steps, self.batch, self.seq_plus1], data,
            )
        } else {
            let data = self.corpus.sample_batch(self.batch, self.seq_plus1);
            Tensor::from_i32(&[self.batch, self.seq_plus1], data)
        }
    }

    /// Run one artifact call (1 or `chunk_steps` optimizer steps).
    /// Returns the mean cross-entropy of the call.
    pub fn step(&mut self) -> Result<f32> {
        let tokens = self.next_batch()?;
        let step_l = Tensor::scalar_i32(self.step).to_literal()?;
        let tok_l = tokens.to_literal()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(2 + self.state.len());
        args.push(&step_l);
        args.push(&tok_l);
        for s in &self.state {
            args.push(s);
        }
        let mut outs = self.runtime.run_literals(&self.artifact, &args)?;
        // outs: [loss(es), params.., m.., v..]
        let n_state = 3 * self.n_params;
        if outs.len() != 1 + n_state {
            bail!("train artifact returned {} outputs, want {}", outs.len(), 1 + n_state);
        }
        let new_state: Vec<xla::Literal> = outs.split_off(1);
        let loss_t = Tensor::from_literal(&outs[0])?;
        self.state = new_state;
        self.step += self.chunk_steps as i32;
        loss_t.mean()
    }

    /// Train for `calls` artifact calls, logging every `log_every`.
    pub fn run(&mut self, calls: usize, log_every: usize) -> Result<TrainLog> {
        let mut log = TrainLog::default();
        let t0 = std::time::Instant::now();
        for c in 0..calls {
            let loss = self.step()?;
            log.losses.push(loss);
            log.tokens_seen += self.batch_tokens() as u64;
            if log_every > 0 && (c + 1) % log_every == 0 {
                let dt = t0.elapsed().as_secs_f64();
                println!(
                    "step {:>5}  loss {:.4}  ({:.1} tok/s)",
                    self.step - 1,
                    loss,
                    log.tokens_seen as f64 / dt
                );
            }
        }
        log.wall_secs = t0.elapsed().as_secs_f64();
        Ok(log)
    }

    /// Current flattened parameters (downloads from literals).
    pub fn params_tensors(&self) -> Result<Vec<Tensor>> {
        self.state[..self.n_params]
            .iter()
            .map(Tensor::from_literal)
            .collect()
    }

    /// Corpus conditional entropy (nats) — the loss floor for reporting.
    pub fn loss_floor(&self) -> f64 {
        self.corpus.conditional_entropy()
    }
}
