//! Shared plumbing for the paper-figure benchmark binaries
//! (`rust/benches/fig*.rs`): random input synthesis from artifact specs,
//! timed artifact execution, and paper-style relative reporting.

use anyhow::Result;

use crate::benchkit::{bench, BenchOpts, Measurement};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{DType, Tensor};

/// Random inputs matching an artifact's spec (f32 ~ N(0, scale); int
/// inputs get small non-negative values; `tokens` get vocab-range ids).
pub fn rand_args(rt: &Runtime, name: &str, rng: &mut Rng, scale: f32) -> Result<Vec<Tensor>> {
    let spec = rt.spec(name)?.clone();
    let vocab = spec.meta_usize("vocab_size").unwrap_or(64) as i32;
    spec.inputs
        .iter()
        .map(|io| {
            let n: usize = io.shape.iter().product();
            Ok(match io.dtype {
                DType::F32 => Tensor::from_f32(&io.shape, rng.normal_vec(n, scale))?,
                DType::I32 => {
                    let hi = if io.name.contains("token") { vocab } else { 2 };
                    let data: Vec<i32> =
                        (0..n).map(|_| rng.below(hi as u64) as i32).collect();
                    Tensor::from_i32(&io.shape, data)?
                }
                DType::U32 => {
                    let data: Vec<u32> =
                        (0..n).map(|_| rng.below(4) as u32).collect();
                    Tensor::from_u32(&io.shape, data)?
                }
            })
        })
        .collect()
}

/// Bench one artifact end-to-end through PJRT: compile (outside timing),
/// then warmup + timed runs per the paper protocol.  The measurement
/// carries the host↔device bytes moved per iteration (from the runtime's
/// transfer counters), so copy costs are reported next to throughput.
pub fn bench_artifact(
    rt: &Runtime, name: &str, label: &str, units_per_iter: f64, opts: BenchOpts,
) -> Result<Measurement> {
    let mut rng = Rng::new(0xBEAC);
    let args = rand_args(rt, name, &mut rng, 0.1)?;
    let lits = rt.to_literals(&args)?;
    let lit_refs: Vec<&xla::Literal> = lits.iter().collect();
    rt.executable(name)?; // compile outside the timed region
    let mut failed: Option<String> = None;
    let xfer0 = rt.transfer_totals();
    let mut iters = 0u64;
    let mut m = bench(label, opts, units_per_iter, || {
        if failed.is_none() {
            iters += 1;
            if let Err(e) = rt.run_literals(name, &lit_refs) {
                failed = Some(format!("{e:#}"));
            }
        }
    });
    if let Some(e) = failed {
        anyhow::bail!("bench {name}: {e}");
    }
    let moved = rt.transfer_totals().since(&xfer0);
    m.set_transfers(&moved, iters);
    Ok(m)
}

/// Open the default runtime for a bench binary.
pub fn open() -> Result<std::sync::Arc<Runtime>> {
    let dir = crate::default_artifact_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "no artifacts at {dir:?}; run `make artifacts` first"
    );
    Ok(std::sync::Arc::new(Runtime::open(&dir)?))
}

/// Print the paper-vs-measured comparison line used in EXPERIMENTS.md.
pub fn paper_check(label: &str, paper: f64, measured: f64) {
    let agree = (measured > 1.0) == (paper > 1.0);
    println!(
        "paper-check  {:<44} paper {:>6.2}x   measured {:>6.2}x   direction {}",
        label,
        paper,
        measured,
        if agree { "MATCHES" } else { "DIFFERS" }
    );
}
