//! Table 1 — implementation-equivalence evaluation: the same trained
//! checkpoint scored by the naive (HF-style) graph and the ScatterMoE
//! graph on a battery of likelihood tasks + perplexity; the per-task
//! absolute error should be ≈ 0 (paper: ≤ 0.006 across 10 tasks,
//! ppl Δ 0.0007).
//!
//! Here the checkpoint is trained on the synthetic corpus through the
//! AOT ScatterMoE train step, then evaluated through BOTH fwd artifacts.

use scattermoe::benchkit::{write_report, Measurement};
use scattermoe::eval::{build_tasks, Evaluator};
use scattermoe::figbench::open;
use scattermoe::tokenizer::SyntheticCorpus;
use scattermoe::train::Trainer;

fn main() -> anyhow::Result<()> {
    let rt = open()?;

    // 1. train a checkpoint (scatter impl) so metrics are non-degenerate
    let calls: usize = std::env::var("SCATTERMOE_T1_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    println!("training the Table-1 checkpoint ({calls} steps on the synthetic corpus)…");
    let mut trainer = Trainer::new(rt.clone(), "lm_bench_init", "lm_bench_train_scatter", 0)?;
    let log = trainer.run(calls, 10)?;
    println!(
        "checkpoint ready: loss {:.3} -> {:.3} (floor {:.3})",
        log.losses[0],
        log.losses.last().unwrap(),
        trainer.loss_floor()
    );
    let params = std::sync::Arc::new(rt.to_literals(&trainer.params_tensors()?)?);

    // 2. evaluate through both implementations
    let ev_scatter = Evaluator::new(rt.clone(), "lm_bench_fwd_scatter", params.clone())?;
    let ev_naive = Evaluator::new(rt.clone(), "lm_bench_fwd_naive", params)?;
    let vocab = trainer.vocab();
    let mut corpus = SyntheticCorpus::new(vocab, 0xE7A1);
    let tasks = build_tasks(&mut corpus, 64);

    println!("\n{:<22} {:>12} {:>12} {:>12}", "Task", "Naive impl", "ScatterMoE", "Abs. Error");
    let mut rows = Vec::new();
    let mut max_err = 0.0f64;
    for task in &tasks {
        let a = ev_naive.accuracy(task)?;
        let s = ev_scatter.accuracy(task)?;
        let err = (a - s).abs();
        max_err = max_err.max(err);
        println!("{:<22} {:>12.4} {:>12.4} {:>12.4}", task.name, a, s, err);
        rows.push(Measurement {
            name: task.name.clone(),
            runs: task.items.len(),
            p5: a,
            median: s,
            p95: err,
            units_per_iter: 0.0,
            host_bytes_per_iter: 0.0,
            up_bytes_per_iter: 0.0,
            down_bytes_per_iter: 0.0,
            chain_bytes_per_iter: 0.0,
        });
    }
    let mut ppl_corpus_a = SyntheticCorpus::new(vocab, 0x99);
    let mut ppl_corpus_b = SyntheticCorpus::new(vocab, 0x99);
    let ppl_a = ev_naive.perplexity(&mut ppl_corpus_a, 4)?;
    let ppl_s = ev_scatter.perplexity(&mut ppl_corpus_b, 4)?;
    let ppl_err = (ppl_a - ppl_s).abs();
    println!(
        "{:<22} {:>12.4} {:>12.4} {:>12.4}",
        "wikitext-syn (ppl)", ppl_a, ppl_s, ppl_err
    );
    rows.push(Measurement {
        name: "wikitext-syn-ppl".into(),
        runs: 4,
        p5: ppl_a,
        median: ppl_s,
        p95: ppl_err,
        units_per_iter: 0.0,
        host_bytes_per_iter: 0.0,
        up_bytes_per_iter: 0.0,
        down_bytes_per_iter: 0.0,
        chain_bytes_per_iter: 0.0,
    });

    println!("\nmax accuracy abs error: {max_err:.5}   ppl abs error: {ppl_err:.5}");
    println!("paper: max abs error 0.006 (accuracy), 0.0007 (ppl) — same property: equivalence");
    anyhow::ensure!(max_err <= 0.02, "implementations diverged on accuracy");
    anyhow::ensure!(ppl_err <= 0.05 * ppl_a, "implementations diverged on ppl");
    println!("EQUIVALENCE HOLDS");
    write_report("bench_reports/table1.json", "table1", &rows);
    Ok(())
}
