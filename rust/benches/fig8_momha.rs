//! Figure 8 — Mixture of Multi-head Attention granularity sweep:
//! k ∈ {1,2,4,8}, E = 8k, h_expert = h/k, shared K/V heads.
//!
//! Paper (k=8): ScatterMoE beats the Megablocks-'dense' MoA baseline by
//! 24.0% inference throughput, and the gap *grows* with granularity
//! (the baseline pays a redundant group/scatter pair around attention).

use scattermoe::benchkit::{print_table, write_report, BenchOpts};
use scattermoe::figbench::{bench_artifact, open, paper_check};

const KS: [usize; 4] = [1, 2, 4, 8];

fn main() -> anyhow::Result<()> {
    let rt = open()?;
    let opts = BenchOpts::default();
    let spec = rt.spec("momha_fwd_scatter_fig8_k1")?.clone();
    let tokens =
        (spec.meta_usize("B").unwrap() * spec.meta_usize("T").unwrap()) as f64;
    println!(
        "Fig 8 config: B={} T={} d_model={} d_head={} h={} ; E=8k, h_expert=h/k",
        spec.meta_usize("B").unwrap(),
        spec.meta_usize("T").unwrap(),
        spec.meta_usize("d_model").unwrap(),
        spec.meta_usize("d_head").unwrap(),
        spec.meta_usize("h").unwrap(),
    );

    let mut rows = Vec::new();
    for mode in ["fwd", "train"] {
        for impl_ in ["scatter", "padded"] {
            for k in KS {
                rows.push(bench_artifact(
                    &rt,
                    &format!("momha_{mode}_{impl_}_fig8_k{k}"),
                    &format!("{impl_} {mode} k={k}"),
                    tokens,
                    opts,
                )?);
            }
        }
    }
    print_table("Fig 8: MoMHA granularity sweep (tokens/s)", &rows, Some("padded fwd k=1"));

    let tp = |n: String| rows.iter().find(|m| m.name == n).unwrap().throughput();
    println!("\nscatter ÷ padded-MoA by granularity (inference):");
    let mut ratios = Vec::new();
    for k in KS {
        let r = tp(format!("scatter fwd k={k}")) / tp(format!("padded fwd k={k}"));
        ratios.push(r);
        println!("  k={k:<2} {r:5.2}x");
    }
    paper_check("scatter vs MB-dense MoA @ max k (paper +24%)", 1.24, *ratios.last().unwrap());
    paper_check(
        "gap grows with granularity (k=8 vs k=1)",
        1.15,
        ratios.last().unwrap() / ratios.first().unwrap(),
    );
    write_report("bench_reports/fig8.json", "8", &rows);
    Ok(())
}
