//! Figure 4b — SMoE MLP unit throughput (training fwd+bwd and inference)
//! for ScatterMoE vs the Megablocks-style padded baseline vs the naive
//! HF-style implementation.
//!
//! Paper (A100, d_model=4096, E=32, k=4, T=30·2048): ScatterMoE slightly
//! faster than MB in training, with a larger margin at inference; naive
//! far behind.  Expected to hold here: the *ordering* and the larger
//! inference margin — absolute numbers are a single CPU core.

use scattermoe::benchkit::{print_table, write_report, BenchOpts};
use scattermoe::figbench::{bench_artifact, open, paper_check};

fn main() -> anyhow::Result<()> {
    let rt = open()?;
    let opts = BenchOpts::default();
    let spec = rt.spec("mlp_fwd_scatter_fig4b")?.clone();
    let tokens = spec.meta_usize("T").unwrap() as f64;
    println!(
        "Fig 4b unit config: T={} d_model={} E={} k={} d_expert={} ({} runs)",
        spec.meta_usize("T").unwrap(),
        spec.meta_usize("d_model").unwrap(),
        spec.meta_usize("E").unwrap(),
        spec.meta_usize("k").unwrap(),
        spec.meta_usize("d_expert").unwrap(),
        opts.runs,
    );

    let mut rows = Vec::new();
    for mode in ["fwd", "train"] {
        for impl_ in ["scatter", "padded", "naive"] {
            let name = format!("mlp_{mode}_{impl_}_fig4b");
            rows.push(bench_artifact(
                &rt,
                &name,
                &format!("{impl_} {mode}"),
                tokens,
                opts,
            )?);
        }
    }
    print_table("Fig 4b: SMoE MLP unit throughput (tokens/s)", &rows, Some("padded fwd"));

    let tp = |n: &str| rows.iter().find(|m| m.name == n).unwrap().throughput();
    let inf_ratio = tp("scatter fwd") / tp("padded fwd");
    let train_ratio = tp("scatter train") / tp("padded train");
    paper_check("scatter/MB inference throughput", 1.25, inf_ratio);
    paper_check("scatter/MB training throughput", 1.10, train_ratio);
    paper_check("naive slower than scatter (fwd)", 0.40, tp("naive fwd") / tp("scatter fwd"));
    if inf_ratio < train_ratio {
        println!("note: paper expects the inference margin to exceed training");
    }
    write_report("bench_reports/fig4b.json", "4b", &rows);
    Ok(())
}
