//! Figure 5 — granularity scaling: k ∈ {1,2,4,8,16}, E = 8k, fixed
//! active parameters (d_expert = d_ff / k, so G = k).
//!
//! Paper: ScatterMoE's throughput relative to Megablocks *grows* with G
//! (padding waste grows with E), and the gap is larger for inference
//! (forward-only) than training.

use scattermoe::benchkit::{print_table, write_report, BenchOpts};
use scattermoe::figbench::{bench_artifact, open, paper_check};

const KS: [usize; 5] = [1, 2, 4, 8, 16];

fn main() -> anyhow::Result<()> {
    let rt = open()?;
    let opts = BenchOpts::default();
    let spec = rt.spec("mlp_fwd_scatter_fig5_k1")?.clone();
    let tokens = spec.meta_usize("T").unwrap() as f64;
    println!(
        "Fig 5 config: T={} d_model={} d_ff(active)={} ; E=8k, d_expert=d_ff/k",
        spec.meta_usize("T").unwrap(),
        spec.meta_usize("d_model").unwrap(),
        spec.meta_usize("d_expert").unwrap(), // k=1: d_expert == d_ff
    );

    // the fixed-active-params dense reference (the paper's relative axis)
    let dense = bench_artifact(&rt, "mlp_fwd_dense_fig5", "dense (active params)", tokens, opts)?;

    let mut rows = vec![dense];
    for mode in ["fwd", "train"] {
        for impl_ in ["scatter", "padded"] {
            for k in KS {
                let name = format!("mlp_{mode}_{impl_}_fig5_k{k}");
                rows.push(bench_artifact(
                    &rt,
                    &name,
                    &format!("{impl_} {mode} G={k} (E={})", 8 * k),
                    tokens,
                    opts,
                )?);
            }
        }
    }
    print_table(
        "Fig 5: granularity sweep (tokens/s, relative to dense active-params)",
        &rows,
        Some("dense (active params)"),
    );

    // the paper's claim: scatter/padded ratio grows with G
    let tp = |n: String| rows.iter().find(|m| m.name == n).unwrap().throughput();
    println!("\nscatter ÷ padded by granularity:");
    let mut first_fwd = 0.0;
    let mut last_fwd = 0.0;
    for k in KS {
        let rf = tp(format!("scatter fwd G={k} (E={})", 8 * k))
            / tp(format!("padded fwd G={k} (E={})", 8 * k));
        let rt_ = tp(format!("scatter train G={k} (E={})", 8 * k))
            / tp(format!("padded train G={k} (E={})", 8 * k));
        println!("  G={k:<3} fwd {rf:5.2}x   train {rt_:5.2}x");
        if k == KS[0] {
            first_fwd = rf;
        }
        if k == KS[KS.len() - 1] {
            last_fwd = rf;
        }
    }
    paper_check("gap grows with G (fwd, G=16 vs G=1)", 1.5, last_fwd / first_fwd);
    write_report("bench_reports/fig5.json", "5", &rows);
    Ok(())
}
