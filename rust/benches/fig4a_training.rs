//! Figure 4a + §4 headline — end-to-end LM training throughput on the
//! scaled Mixtral configuration (paper: d_model=1024, d_expert=3584,
//! k=2, E=8, L=16 on 8×A100; here ÷4 width and depth on one CPU core).
//!
//! Paper: ScatterMoE > MB (sparse) by **38.1%**, > MB (mem. eff.) and
//! >> naive HF.  The paper attributes part of the gap to memory: at
//! fixed device memory Megablocks needs half the micro-batch and twice
//! the accumulation steps.  We report both the kernel-level step
//! throughput and the memory-derived micro-batch feasibility factor
//! from the allocator model.

use scattermoe::benchkit::{bench, print_table, write_report, BenchOpts};
use scattermoe::figbench::{open, paper_check};
use scattermoe::memmodel::{padded_footprint, scatter_footprint, MlpShape};
use scattermoe::train::Trainer;

fn main() -> anyhow::Result<()> {
    let rt = open()?;
    let opts = BenchOpts { warmup: 1, runs: BenchOpts::default().runs.min(8) };
    let spec = rt.spec("lm_bench_train_scatter")?.clone();
    println!(
        "Fig 4a config: {} params, L={} d_model={} E={} k={} d_expert={} batch={} seq={}",
        spec.meta_usize("param_count").unwrap(),
        spec.meta_usize("n_layers").unwrap(),
        spec.meta_usize("d_model").unwrap(),
        spec.meta_usize("num_experts").unwrap(),
        spec.meta_usize("top_k").unwrap(),
        spec.meta_usize("d_expert").unwrap(),
        spec.meta_usize("batch").unwrap(),
        spec.meta_usize("seq").unwrap(),
    );

    let mut rows = Vec::new();
    for impl_ in ["scatter", "padded", "naive"] {
        let mut trainer = Trainer::new(
            rt.clone(),
            "lm_bench_init",
            &format!("lm_bench_train_{impl_}"),
            0,
        )?;
        let tokens = trainer.batch_tokens() as f64;
        trainer.step()?; // compile + first step outside timing
        let mut failed = None;
        let xfer0 = rt.transfer_totals();
        let mut iters = 0u64;
        let mut m = bench(&format!("{impl_} train step"), opts, tokens, || {
            if failed.is_none() {
                iters += 1;
                if let Err(e) = trainer.step() {
                    failed = Some(e);
                }
            }
        });
        if let Some(e) = failed {
            return Err(e);
        }
        // per-step host↔device traffic: the optimizer-state round-trip
        // the scan-chunked artifacts amortise (see lm_e2e)
        let moved = rt.transfer_totals().since(&xfer0);
        if iters > 0 {
            m.host_bytes_per_iter = moved.total_bytes() as f64 / iters as f64;
        }
        rows.push(m);
    }
    print_table(
        "Fig 4a: 1.5B-scaled Mixtral training throughput (tokens/s)",
        &rows,
        Some("padded train step"),
    );

    let tp = |n: &str| rows.iter().find(|m| m.name == n).unwrap().throughput();
    let step_ratio = tp("scatter train step") / tp("padded train step");

    // memory-feasibility factor: at fixed HBM the micro-batch Megablocks
    // can fit is scatter/padded smaller (paper ran MB at half batch,
    // double accumulation)
    let shape = MlpShape {
        tokens: spec.meta_usize("batch").unwrap() * spec.meta_usize("seq").unwrap(),
        k: spec.meta_usize("top_k").unwrap(),
        num_experts: spec.meta_usize("num_experts").unwrap(),
        d_model: spec.meta_usize("d_model").unwrap(),
        d_expert: spec.meta_usize("d_expert").unwrap(),
        block: 128,
        dtype_bytes: 4,
    };
    let counts = shape.balanced_counts();
    let mem_factor = padded_footprint(&shape, &counts, true).total() as f64
        / scatter_footprint(&shape, true).total() as f64;
    println!(
        "\nkernel-level step speedup            : {:.2}x",
        step_ratio
    );
    println!(
        "memory-derived micro-batch advantage : {:.2}x (MB fits a {:.0}% batch)",
        mem_factor,
        100.0 / mem_factor
    );
    let combined = step_ratio * mem_factor.min(2.0).max(1.0).sqrt();
    println!(
        "combined end-to-end estimate         : {:.2}x  (paper headline: 1.38x)",
        combined
    );
    paper_check("§4 headline: scatter vs MB(sparse) training", 1.381, combined);
    paper_check("naive is the slowest", 0.5, tp("naive train step") / tp("scatter train step"));
    write_report("bench_reports/fig4a.json", "4a", &rows);
    Ok(())
}
