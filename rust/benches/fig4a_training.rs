//! Figure 4a + §4 headline — end-to-end LM training throughput on the
//! scaled Mixtral configuration (paper: d_model=1024, d_expert=3584,
//! k=2, E=8, L=16 on 8×A100; here ÷4 width and depth on one CPU core).
//!
//! Paper: ScatterMoE > MB (sparse) by **38.1%**, > MB (mem. eff.) and
//! >> naive HF.  The paper attributes part of the gap to memory: at
//! fixed device memory Megablocks needs half the micro-batch and twice
//! the accumulation steps.  We report both the kernel-level step
//! throughput and the memory-derived micro-batch feasibility factor
//! from the allocator model.

use scattermoe::benchkit::{bench, print_table, write_report, BenchOpts};
use scattermoe::figbench::{open, paper_check};
use scattermoe::memmodel::{padded_footprint, scatter_footprint, MlpShape};
use scattermoe::metrics::{fmt_bytes, fmt_reduction};
use scattermoe::train::{StatePlacement, Trainer};

fn main() -> anyhow::Result<()> {
    let rt = open()?;
    let opts = BenchOpts { warmup: 1, runs: BenchOpts::default().runs.min(8) };
    let spec = rt.spec("lm_bench_train_scatter")?.clone();
    println!(
        "Fig 4a config: {} params, L={} d_model={} E={} k={} d_expert={} batch={} seq={}",
        spec.meta_usize("param_count").unwrap(),
        spec.meta_usize("n_layers").unwrap(),
        spec.meta_usize("d_model").unwrap(),
        spec.meta_usize("num_experts").unwrap(),
        spec.meta_usize("top_k").unwrap(),
        spec.meta_usize("d_expert").unwrap(),
        spec.meta_usize("batch").unwrap(),
        spec.meta_usize("seq").unwrap(),
    );

    // each impl trains on the device-resident path (the default), plus
    // one host-literal run of scatter as the bytes-per-step "before"
    let series: &[(&str, StatePlacement, &str)] = &[
        ("scatter", StatePlacement::Device, "scatter train step"),
        ("padded", StatePlacement::Device, "padded train step"),
        ("naive", StatePlacement::Device, "naive train step"),
        ("scatter", StatePlacement::Host, "scatter train step (host state)"),
    ];
    let mut rows = Vec::new();
    let mut state_bytes = 0usize;
    let mut device_path_live = true;
    for &(impl_, placement, label) in series {
        let mut trainer = Trainer::new_with_placement(
            rt.clone(),
            "lm_bench_init",
            &format!("lm_bench_train_{impl_}"),
            0,
            placement,
        )?;
        if placement == StatePlacement::Device
            && trainer.placement() != StatePlacement::Device
        {
            // pre-chain_map artifact dir: the Trainer fell back to host
            // literals, so a before/after comparison would be host-vs-host
            device_path_live = false;
        }
        state_bytes = trainer.state_bytes();
        let tokens = trainer.batch_tokens() as f64;
        trainer.step()?; // compile + first step outside timing
        let mut failed = None;
        let xfer0 = rt.transfer_totals();
        let mut iters = 0u64;
        let mut m = bench(label, opts, tokens, || {
            if failed.is_none() {
                iters += 1;
                if let Err(e) = trainer.step() {
                    failed = Some(e);
                }
            }
        });
        if let Some(e) = failed {
            return Err(e);
        }
        // per-step host↔device traffic: O(tokens) on the chained path,
        // O(param count) on the host-literal baseline
        let moved = rt.transfer_totals().since(&xfer0);
        m.set_transfers(&moved, iters);
        rows.push(m);
    }
    print_table(
        "Fig 4a: 1.5B-scaled Mixtral training throughput (tokens/s)",
        &rows,
        Some("padded train step"),
    );

    // the paper's copy-elimination claim, applied to the train loop:
    // steady-state staged bytes must not scale with the parameter count
    let row = |n: &str| rows.iter().find(|m| m.name == n).unwrap();
    let chained = row("scatter train step");
    let literal = row("scatter train step (host state)");
    println!(
        "\none (params+m+v) state copy          : {}",
        fmt_bytes(state_bytes as u64)
    );
    if device_path_live {
        println!(
            "host->device staged per step         : {}",
            fmt_reduction(
                literal.up_bytes_per_iter as u64,
                chained.up_bytes_per_iter as u64
            )
        );
        println!(
            "total host<->device per step         : {}",
            fmt_reduction(
                literal.host_bytes_per_iter as u64,
                chained.host_bytes_per_iter as u64
            )
        );
        if chained.chain_bytes_per_iter > 0.0 {
            println!(
                "NOTE: fallback tuple round-trips moved {}/step (xla crate fused \
                 the output tuple — measured, not hidden)",
                fmt_bytes(chained.chain_bytes_per_iter as u64)
            );
        }
    } else {
        println!(
            "NOTE: artifacts predate chain_map — every row above ran with \
             host-literal state, before/after comparison skipped \
             (re-run `make artifacts`)"
        );
    }

    let tp = |n: &str| rows.iter().find(|m| m.name == n).unwrap().throughput();
    let step_ratio = tp("scatter train step") / tp("padded train step");

    // memory-feasibility factor: at fixed HBM the micro-batch Megablocks
    // can fit is scatter/padded smaller (paper ran MB at half batch,
    // double accumulation)
    let shape = MlpShape {
        tokens: spec.meta_usize("batch").unwrap() * spec.meta_usize("seq").unwrap(),
        k: spec.meta_usize("top_k").unwrap(),
        num_experts: spec.meta_usize("num_experts").unwrap(),
        d_model: spec.meta_usize("d_model").unwrap(),
        d_expert: spec.meta_usize("d_expert").unwrap(),
        block: 128,
        dtype_bytes: 4,
    };
    let counts = shape.balanced_counts();
    let mem_factor = padded_footprint(&shape, &counts, true).total() as f64
        / scatter_footprint(&shape, true).total() as f64;
    println!(
        "\nkernel-level step speedup            : {:.2}x",
        step_ratio
    );
    println!(
        "memory-derived micro-batch advantage : {:.2}x (MB fits a {:.0}% batch)",
        mem_factor,
        100.0 / mem_factor
    );
    let combined = step_ratio * mem_factor.min(2.0).max(1.0).sqrt();
    println!(
        "combined end-to-end estimate         : {:.2}x  (paper headline: 1.38x)",
        combined
    );
    paper_check("§4 headline: scatter vs MB(sparse) training", 1.381, combined);
    paper_check("naive is the slowest", 0.5, tp("naive train step") / tp("scatter train step"));
    write_report("bench_reports/fig4a.json", "4a", &rows);
    Ok(())
}
