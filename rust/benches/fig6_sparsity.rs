//! Figure 6 — decreasing sparsity: growing k at fixed E = 64, compared
//! to a fully dense MLP with d_ff = E · d_expert (same *total* params).
//!
//! Paper: both SMoE implementations beat the big dense model while k is
//! small; by k ≈ 30 the SMoE overhead (routing, sorting, copies) eats
//! the sparsity advantage and throughput approaches the dense line.

use scattermoe::benchkit::{print_table, write_report, BenchOpts};
use scattermoe::figbench::{bench_artifact, open, paper_check};

const KS: [usize; 6] = [2, 4, 8, 16, 24, 30];

fn main() -> anyhow::Result<()> {
    let rt = open()?;
    let opts = BenchOpts::default();
    let spec = rt.spec("mlp_fwd_scatter_fig6_k2")?.clone();
    let tokens = spec.meta_usize("T").unwrap() as f64;
    println!(
        "Fig 6 config: T={} d_model={} E=64 d_expert={} ; dense d_ff = {}",
        spec.meta_usize("T").unwrap(),
        spec.meta_usize("d_model").unwrap(),
        spec.meta_usize("d_expert").unwrap(),
        64 * spec.meta_usize("d_expert").unwrap(),
    );

    let dense = bench_artifact(
        &rt, "mlp_fwd_dense_fig6", "dense (total params)", tokens, opts,
    )?;
    let mut rows = vec![dense];
    for impl_ in ["scatter", "padded"] {
        for k in KS {
            rows.push(bench_artifact(
                &rt,
                &format!("mlp_fwd_{impl_}_fig6_k{k}"),
                &format!("{impl_} k={k}"),
                tokens,
                opts,
            )?);
        }
    }
    print_table(
        "Fig 6: decreasing sparsity (tokens/s, relative to equal-total-params dense)",
        &rows,
        Some("dense (total params)"),
    );

    let tp = |n: String| rows.iter().find(|m| m.name == n).unwrap().throughput();
    let dense_tp = rows[0].throughput();
    let k_small = tp(format!("scatter k={}", KS[0])) / dense_tp;
    let k_large = tp(format!("scatter k={}", KS[KS.len() - 1])) / dense_tp;
    paper_check("sparse >> dense at small k", 4.0, k_small);
    paper_check("advantage shrinks by k=30 (rel. to small k)", 0.25, k_large / k_small);
    // scatter stays at or above padded across the sweep
    let mut ok = true;
    for k in KS {
        ok &= tp(format!("scatter k={k}")) >= 0.9 * tp(format!("padded k={k}"));
    }
    println!("scatter >= padded across sweep: {}", if ok { "yes" } else { "NO" });
    write_report("bench_reports/fig6.json", "6", &rows);
    Ok(())
}
