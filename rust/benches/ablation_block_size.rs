//! Design ablation (DESIGN.md §7): GEMM row-block size vs padding waste
//! and memory, across routing-imbalance regimes.
//!
//! The paper fixes block 128 (the MXU/tensor-core native tile).  This
//! ablation quantifies the trade-off that choice encodes: bigger blocks
//! raise MXU utilisation per pass but waste more padding on imbalanced
//! experts — the effect behind Fig 5's Megablocks degradation.  Uses the
//! analytic models only (no kernel execution), so it also documents the
//! *mechanism* independently of interpret-mode noise.

use scattermoe::benchkit::{write_report, Measurement};
use scattermoe::coordinator::ExpertStats;
use scattermoe::memmodel::{padded_footprint, scatter_footprint, MlpShape};
use scattermoe::rng::Rng;

fn skewed_counts(slots: usize, e: usize, hot_frac: f64, rng: &mut Rng) -> Vec<usize> {
    let hot = (slots as f64 * hot_frac) as usize;
    let mut counts = vec![0usize; e];
    counts[0] = hot;
    for _ in 0..slots - hot {
        counts[1 + rng.below((e - 1) as u64) as usize] += 1;
    }
    counts
}

fn main() -> anyhow::Result<()> {
    let base = MlpShape {
        tokens: 8192,
        k: 4,
        num_experts: 64,
        d_model: 512,
        d_expert: 256,
        block: 128,
        dtype_bytes: 4,
    };
    let mut rng = Rng::new(11);
    let mut rows = Vec::new();

    println!(
        "ablation: T={} k={} E={} — padding waste & memory ratio by (block, skew)",
        base.tokens, base.k, base.num_experts
    );
    println!(
        "{:>6} {:>10} {:>14} {:>16} {:>16}",
        "block", "skew", "pad waste", "scatter/padded", "scatter/padded"
    );
    println!(
        "{:>6} {:>10} {:>14} {:>16} {:>16}",
        "", "", "(rows)", "(inference)", "(training)"
    );
    for &block in &[8usize, 32, 128, 512] {
        for &(label, hot) in &[("balanced", 0.0f64), ("mild", 0.3), ("hot-expert", 0.7)] {
            let shape = MlpShape { block, ..base };
            let counts = if hot == 0.0 {
                shape.balanced_counts()
            } else {
                skewed_counts(shape.slots(), shape.num_experts, hot, &mut rng)
            };
            let mut stats = ExpertStats::new(shape.num_experts);
            stats.record_counts(&counts.iter().map(|&c| c as u64).collect::<Vec<_>>());
            let waste = stats.padding_waste(block as u64);
            let inf = scatter_footprint(&shape, false).total() as f64
                / padded_footprint(&shape, &counts, false).total() as f64;
            let tr = scatter_footprint(&shape, true).total() as f64
                / padded_footprint(&shape, &counts, true).total() as f64;
            println!(
                "{:>6} {:>10} {:>13.1}% {:>15.1}% {:>15.1}%",
                block, label, waste * 100.0, inf * 100.0, tr * 100.0
            );
            rows.push(Measurement {
                name: format!("block{block}-{label}"),
                runs: 1,
                p5: waste,
                median: inf,
                p95: tr,
                units_per_iter: 0.0,
                host_bytes_per_iter: 0.0,
                up_bytes_per_iter: 0.0,
                down_bytes_per_iter: 0.0,
                chain_bytes_per_iter: 0.0,
            });
        }
    }
    println!(
        "\nreading: ScatterMoE's ratio *improves* (falls) with both block size and\n\
         skew because only the padded baseline materialises the wasted rows —\n\
         the paper's Fig 5 mechanism, isolated."
    );
    write_report("bench_reports/ablation_block_size.json", "ablation", &rows);
    Ok(())
}
