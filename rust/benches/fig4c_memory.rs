//! Figure 4c — SMoE MLP memory footprint: ScatterMoE vs the
//! Megablocks-style padded pipeline vs the naive baseline.
//!
//! Paper (A100, unit config): ScatterMoE uses **66.2%** of Megablocks'
//! memory in training and **53.6%** at inference.  The analytic HBM
//! allocator model (`memmodel`, DESIGN.md §2 substitution for
//! nvidia-smi) reproduces the allocation strategy of each algorithm; the
//! live-XLA cross-check runs in `python/tests/test_memory.py`.

use scattermoe::benchkit::write_report;
use scattermoe::benchkit::Measurement;
use scattermoe::figbench::paper_check;
use scattermoe::memmodel::{
    capacity_footprint, naive_footprint, padded_footprint, scatter_footprint,
    scatter_vs_padded_ratio, KvCacheShape, MlpShape,
};

fn mem_row(name: String, bytes: usize) -> Measurement {
    Measurement::scalar(name, bytes as f64)
}

fn main() -> anyhow::Result<()> {
    let shape = MlpShape::paper_unit();
    println!(
        "Fig 4c config (paper unit): T={} k={} E={} d_model={} d_expert={} block={}",
        shape.tokens, shape.k, shape.num_experts, shape.d_model,
        shape.d_expert, shape.block
    );
    let counts = shape.balanced_counts();

    let mut rows = Vec::new();
    for training in [false, true] {
        let fps = [
            scatter_footprint(&shape, training),
            padded_footprint(&shape, &counts, training),
            naive_footprint(&shape, training),
            capacity_footprint(&shape, 1.25, training),
        ];
        println!(
            "\n================ {} ================",
            if training { "TRAINING" } else { "INFERENCE" }
        );
        for fp in &fps {
            fp.print();
            rows.push(mem_row(
                format!(
                    "{} {}",
                    fp.strategy,
                    if training { "train" } else { "infer" }
                ),
                fp.total(),
            ));
        }
    }

    let inf = scatter_vs_padded_ratio(&shape, &counts, false);
    let tr = scatter_vs_padded_ratio(&shape, &counts, true);
    println!("\nscatter / padded memory ratio:");
    println!("  inference: {:.1}%   (paper: 53.6%)", inf * 100.0);
    println!("  training:  {:.1}%   (paper: 66.2%)", tr * 100.0);
    paper_check("inference memory ratio < 1", 0.536, inf);
    paper_check("training memory ratio < 1", 0.662, tr);

    // imbalance ablation: padding waste under a hot-expert distribution
    let mut skew = counts.clone();
    let moved = skew.iter().skip(1).map(|&c| c / 2).sum::<usize>();
    for c in skew.iter_mut().skip(1) {
        *c -= *c / 2;
    }
    skew[0] += moved;
    let tr_skew = scatter_vs_padded_ratio(&shape, &skew, true);
    println!(
        "under 50% hot-expert skew the ratio improves to {:.1}% (padding grows)",
        tr_skew * 100.0
    );
    // ---- serving KV cache: dense worst-case vs paged pools ----
    // the same padding-elimination story on the attention side: the
    // dense cache pads every slot to max_len, the paged pool holds only
    // the pages actual contexts touch (+1 reserved garbage page)
    let kv = KvCacheShape::serve_default();
    println!(
        "\n================ SERVING KV CACHE ================\n\
         geometry: L={} B={} Tmax={} nh={} dh={} page={}",
        kv.layers, kv.slots, kv.max_len, kv.n_heads, kv.d_head, kv.page_size
    );
    let dense = kv.dense_bytes();
    let mut kv_rows = vec![mem_row("kv dense (worst case)".into(), dense)];
    println!("  dense worst case: {:>10} bytes", dense);
    for frac in [8, 4, 2, 1] {
        let ctx = kv.max_len / frac;
        let paged = kv.paged_bytes(&vec![ctx; kv.slots]);
        println!(
            "  paged @ mean ctx {:>4} ({:>4}% of Tmax): {:>10} bytes  ({:>5.1}% of dense)",
            ctx,
            100 / frac,
            paged,
            100.0 * paged as f64 / dense as f64
        );
        kv_rows.push(mem_row(format!("kv paged ctx={ctx}"), paged));
    }
    let crossover = kv.crossover_context();
    println!(
        "  paged is strictly smaller up to mean context {} / {} \
         (crossover at {:.0}% of Tmax)",
        crossover,
        kv.max_len,
        100.0 * crossover as f64 / kv.max_len as f64
    );
    paper_check(
        "paged/dense cache ratio at Tmax/2 < 1",
        0.5,
        kv.paged_vs_dense_ratio(kv.max_len / 2),
    );

    // ---- lazy growth + copy-on-write prefix sharing (PR 4) ----
    // lazy admission commits the same worst case (reservation ledger)
    // but only *materialises* prompt pages + one decode page, growing
    // with pos — the resident-bytes gap below; prefix sharing shrinks
    // each later admission's commitment by the refcounted common-prefix
    // pages — the admitted-width gap.
    println!("\n---- lazy growth vs eager admission (resident pool bytes) ----");
    let reqs: Vec<(usize, usize)> = (0..kv.slots).map(|i| (16 + 8 * (i % 3), 64)).collect();
    let eager = kv.eager_resident_bytes(&reqs);
    let early = kv.lazy_resident_bytes(&reqs, &vec![0; kv.slots]);
    let mid = kv.lazy_resident_bytes(&reqs, &vec![32; kv.slots]);
    println!(
        "  eager (worst case at admit): {eager:>9} bytes\n  \
         lazy at admission:           {early:>9} bytes  ({:>5.1}% of eager)\n  \
         lazy at half budget:         {mid:>9} bytes  ({:>5.1}% of eager)",
        100.0 * early as f64 / eager as f64,
        100.0 * mid as f64 / eager as f64,
    );
    kv_rows.push(mem_row("kv resident eager (worst case)".into(), eager));
    kv_rows.push(mem_row("kv resident lazy @ admission".into(), early));
    kv_rows.push(mem_row("kv resident lazy @ half budget".into(), mid));

    println!("---- admitted batch width (pool-limited, 120-token prompts) ----");
    let (plen, budget) = (120, 40);
    let w_base = kv.admitted_width(plen, budget, 0);
    let w_shared = kv.admitted_width(plen, budget, plen);
    println!(
        "  no sharing: {w_base} requests   shared prefix ({} full pages): \
         {w_shared} requests  ({}x)",
        plen / kv.page_size,
        w_shared as f64 / w_base.max(1) as f64,
    );
    kv_rows.push(mem_row("kv admitted width (no sharing)".into(), w_base));
    kv_rows.push(mem_row("kv admitted width (shared prefix)".into(), w_shared));
    paper_check(
        "shared-prefix admitted width gain > 1",
        2.0,
        w_shared as f64 / w_base.max(1) as f64,
    );

    // ---- two-tier overcommit: admitted width vs tail latency (PR 9) ----
    // the reservation ledger may promise growth past the free list
    // (fresh pages never overcommit); a dry growth step preempts a
    // victim whose pages pin to the host tier and whose seed replay
    // prices the tail.  Decode-heavy requests (small fresh, large
    // reserve) are where the factor buys width.
    let (oc_plen, oc_budget) = (8usize, 120usize);
    println!(
        "\n---- overcommitted ledger ({oc_plen}-token prompts, {oc_budget} decode budget) ----"
    );
    let factors = [1.0, 1.5, 2.0, 3.0];
    let curve = kv.width_latency_tradeoff(oc_plen, oc_budget, 0, &factors);
    for &(f, w, tail) in &curve {
        let v = kv.preempted_victims(oc_plen, oc_budget, 0, w);
        println!(
            "  factor {f:>3.1}: {w:>2} admitted  {v:>2} preempted victims  \
             worst-victim tail x{tail:.1}"
        );
    }
    let strict_w = curve[0].1;
    let (oc_factor, oc_w, oc_tail) = curve[2];
    let oc_victims = kv.preempted_victims(oc_plen, oc_budget, 0, oc_w);
    let tier = kv.host_tier_pin_bytes(oc_plen, oc_budget, 0, oc_victims);
    println!(
        "  at factor {oc_factor:.1}: {oc_w} admitted ({:.1}x the strict {strict_w}) \
         for a x{oc_tail:.1} tail — host tier pins {tier} bytes",
        oc_w as f64 / strict_w.max(1) as f64,
    );
    kv_rows.push(mem_row("kv overcommit admitted width (factor 2)".into(), oc_w));
    kv_rows.push(mem_row("kv overcommit preempted victims (factor 2)".into(), oc_victims));
    kv_rows.push(mem_row("kv host tier bytes (pinned victims)".into(), tier));
    paper_check(
        "overcommit admitted-width gain > 1",
        2.0,
        oc_w as f64 / strict_w.max(1) as f64,
    );

    // ---- retained prefix pool: the hot-system-prompt scenario (PR 5) ----
    // In-flight CoW sharing dies with its last block table; the retained
    // pool parks prompt-prefix pages across idle gaps, so a hot system
    // prompt is written once and then served from the pool.  Model: n
    // sequential requests (no overlap), page-aligned 128-token prompt.
    let (hot_len, n_reqs) = (128usize, 16usize);
    let park_bytes = kv.retained_pool_bytes(hot_len);
    let cold = kv.hot_prompt_pages_written(hot_len, n_reqs, false);
    let warm = kv.hot_prompt_pages_written(hot_len, n_reqs, true);
    println!(
        "\n---- retained prefix pool (hot system prompt, {hot_len} tokens × {n_reqs} requests) ----\n  \
         retained pool holds:          {park_bytes:>9} bytes between requests\n  \
         prompt pages written, no retention: {cold:>4}\n  \
         prompt pages written, retention:    {warm:>4}  ({:.1}x fewer)",
        cold as f64 / warm.max(1) as f64,
    );
    kv_rows.push(mem_row(
        format!("kv retained pool bytes ({hot_len}-token prefix)"),
        park_bytes,
    ));
    kv_rows.push(mem_row(
        format!("kv hot-prompt pages written x{n_reqs} (no retention)"),
        cold,
    ));
    kv_rows.push(mem_row(
        format!("kv hot-prompt pages written x{n_reqs} (retention)"),
        warm,
    ));
    paper_check(
        "retained-prefix hot-prompt write reduction > 1",
        n_reqs as f64,
        cold as f64 / warm.max(1) as f64,
    );
    rows.extend_from_slice(&kv_rows);
    write_report("bench_reports/fig4c.json", "4c", &rows);
    // machine-readable trajectory: cache bytes per layout across PRs
    write_report("bench_reports/BENCH_memory.json", "4c-kv", &kv_rows);
    Ok(())
}
