//! Figure 4c — SMoE MLP memory footprint: ScatterMoE vs the
//! Megablocks-style padded pipeline vs the naive baseline.
//!
//! Paper (A100, unit config): ScatterMoE uses **66.2%** of Megablocks'
//! memory in training and **53.6%** at inference.  The analytic HBM
//! allocator model (`memmodel`, DESIGN.md §2 substitution for
//! nvidia-smi) reproduces the allocation strategy of each algorithm; the
//! live-XLA cross-check runs in `python/tests/test_memory.py`.

use scattermoe::benchkit::write_report;
use scattermoe::benchkit::Measurement;
use scattermoe::figbench::paper_check;
use scattermoe::memmodel::{
    capacity_footprint, naive_footprint, padded_footprint, scatter_footprint,
    scatter_vs_padded_ratio, MlpShape,
};

fn main() -> anyhow::Result<()> {
    let shape = MlpShape::paper_unit();
    println!(
        "Fig 4c config (paper unit): T={} k={} E={} d_model={} d_expert={} block={}",
        shape.tokens, shape.k, shape.num_experts, shape.d_model,
        shape.d_expert, shape.block
    );
    let counts = shape.balanced_counts();

    let mut rows = Vec::new();
    for training in [false, true] {
        let fps = [
            scatter_footprint(&shape, training),
            padded_footprint(&shape, &counts, training),
            naive_footprint(&shape, training),
            capacity_footprint(&shape, 1.25, training),
        ];
        println!(
            "\n================ {} ================",
            if training { "TRAINING" } else { "INFERENCE" }
        );
        for fp in &fps {
            fp.print();
            rows.push(Measurement {
                name: format!(
                    "{} {}",
                    fp.strategy,
                    if training { "train" } else { "infer" }
                ),
                runs: 1,
                p5: fp.total() as f64,
                median: fp.total() as f64,
                p95: fp.total() as f64,
                units_per_iter: 0.0,
                host_bytes_per_iter: 0.0,
                up_bytes_per_iter: 0.0,
                down_bytes_per_iter: 0.0,
                chain_bytes_per_iter: 0.0,
            });
        }
    }

    let inf = scatter_vs_padded_ratio(&shape, &counts, false);
    let tr = scatter_vs_padded_ratio(&shape, &counts, true);
    println!("\nscatter / padded memory ratio:");
    println!("  inference: {:.1}%   (paper: 53.6%)", inf * 100.0);
    println!("  training:  {:.1}%   (paper: 66.2%)", tr * 100.0);
    paper_check("inference memory ratio < 1", 0.536, inf);
    paper_check("training memory ratio < 1", 0.662, tr);

    // imbalance ablation: padding waste under a hot-expert distribution
    let mut skew = counts.clone();
    let moved = skew.iter().skip(1).map(|&c| c / 2).sum::<usize>();
    for c in skew.iter_mut().skip(1) {
        *c -= *c / 2;
    }
    skew[0] += moved;
    let tr_skew = scatter_vs_padded_ratio(&shape, &skew, true);
    println!(
        "under 50% hot-expert skew the ratio improves to {:.1}% (padding grows)",
        tr_skew * 100.0
    );
    write_report("bench_reports/fig4c.json", "4c", &rows);
    Ok(())
}
